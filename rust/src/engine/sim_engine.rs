//! Bullet's serving loop on the simulated GPU: concurrent prefill and
//! decode with dynamic SM partitioning, driven by a virtual-clock event
//! loop.
//!
//! Fidelity notes vs the paper's live system:
//! - the prefill engine launches one *layer group* at a time and makes a
//!   scheduling decision at every group boundary (§3.3.1);
//! - the decode engine launches whole iterations (CUDA-graph analog) and
//!   decides before each one;
//! - a decode *pause* skips the next decode iteration, waking at the next
//!   prefill group boundary (§3.4.2-②);
//! - prefill→decode migration is copy-free through the shared KV pool;
//!   requests join the decode batch at the next iteration boundary;
//! - KV capacity is reserved for input+output at prefill admission, so a
//!   running request can never deadlock the pool mid-decode (documented
//!   deviation: the paper allocates decode blocks on demand).

use crate::config::ServingConfig;
use crate::gpu::roofline::GroundTruth;
use crate::gpu::simulator::Simulator;
use crate::kvcache::KvPool;
use crate::metrics::timeline::{Timeline, TimelineSample};
use crate::metrics::RequestRecord;
use crate::model::phases::{decode_all_layers, prefill_layer_kernels, PhaseShape};
use crate::perf::PerfModel;
use crate::resource::{Partition, ResourceManager};
use crate::sched::{Decision, DecodeReqState, PrefillBatch, PrefillReq, SloScheduler, SystemState};
use crate::workload::Request;

/// Feature switches: the full system runs with everything on; the
/// Fig. 13/14 baselines disable individual mechanisms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Features {
    /// Dynamic SM partitioning (off ⇒ both phases use fixed masks).
    pub dynamic_partition: bool,
    /// SLO-slack reordering of the waiting queue.
    pub reorder: bool,
    /// Temporary decode pausing under TTFT pressure.
    pub pause: bool,
    /// With `dynamic_partition = false`: prefill's fixed SM count
    /// (`None` ⇒ whole GPU).  Decode always gets the whole GPU in the
    /// fixed configurations, as in the paper's sensitivity study (§4.4).
    pub fixed_prefill_sms: Option<usize>,
}

impl Default for Features {
    fn default() -> Self {
        Features {
            dynamic_partition: true,
            reorder: true,
            pause: true,
            fixed_prefill_sms: None,
        }
    }
}

impl Features {
    /// The "Naive" ablation: concurrency only.
    pub fn naive() -> Features {
        Features {
            dynamic_partition: false,
            reorder: false,
            pause: false,
            fixed_prefill_sms: None,
        }
    }

    /// "w/Partition": resource provisioning without the SLO scheduler.
    pub fn partition_only() -> Features {
        Features {
            dynamic_partition: true,
            reorder: false,
            pause: false,
            fixed_prefill_sms: None,
        }
    }

    /// "w/Scheduler": reordering + delayed decode, no partitioning.
    pub fn scheduler_only() -> Features {
        Features {
            dynamic_partition: false,
            reorder: true,
            pause: true,
            fixed_prefill_sms: None,
        }
    }

    /// Fixed prefill quota (MuxServe-style / Fig. 13 sensitivity points).
    pub fn fixed(prefill_sms: usize) -> Features {
        Features {
            dynamic_partition: false,
            reorder: false,
            pause: false,
            fixed_prefill_sms: Some(prefill_sms),
        }
    }
}

/// Engine options.
#[derive(Debug, Clone)]
pub struct SimEngineOptions {
    pub seed: u64,
    /// Record a timeline sample at every scheduling decision.
    pub record_timeline: bool,
    /// Hard cap on virtual time (safety against pathological configs).
    pub max_virtual_time: f64,
    pub features: Features,
}

impl Default for SimEngineOptions {
    fn default() -> Self {
        SimEngineOptions {
            seed: 0xB17,
            record_timeline: false,
            max_virtual_time: 50_000.0,
            features: Features::default(),
        }
    }
}

/// Everything a serving run produces.
#[derive(Debug, Clone)]
pub struct EngineOutput {
    pub records: Vec<RequestRecord>,
    pub timeline: Timeline,
    pub reconfigs: u64,
    pub decode_pauses: u64,
    /// Total achieved FLOPs / bytes / SM-seconds (whole run).
    pub total_flops: f64,
    pub total_bytes: f64,
    pub virtual_duration: f64,
    pub peak_kv_blocks: usize,
}

struct ActiveDecode {
    st: DecodeReqState,
    arrival: f64,
    prefill_start: f64,
    first_token_time: f64,
    /// Virtual time of this request's latest token — TPOT accounting
    /// charges the FULL gap between tokens (queueing, pauses, contention),
    /// as the paper's d_i does, so the scheduler cannot hide stalls.
    last_token_time: f64,
}

/// Serve `trace` with the full Bullet engine; returns per-request records.
pub fn serve_bullet(
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    opts: &SimEngineOptions,
) -> EngineOutput {
    let mut sim = Simulator::new(gt.clone(), opts.seed);
    let mut rm = ResourceManager::new(&mut sim, &cfg.gpu);
    let sched = SloScheduler::new(cfg.clone(), perf.clone());
    let mut kv = KvPool::new(cfg.kv_capacity_tokens);
    let mut timeline = Timeline::new();

    let total_layers = cfg.model.n_layers;
    let mut waiting: Vec<PrefillReq> = Vec::new();
    let mut active_prefill: Option<PrefillBatch> = None;
    let mut prefill_inflight = 0usize; // kernels outstanding in current group
    let mut group_size = 0usize; // layers in the current group
    let mut decode: Vec<ActiveDecode> = Vec::new();
    let mut decode_inflight = 0usize;
    let mut decode_iter_start = 0.0f64;
    let mut decode_iter_bs = 0usize;
    let mut pending_join: Vec<ActiveDecode> = Vec::new();
    let mut paused_decode = false;
    let mut decode_pauses = 0u64;
    let mut records: Vec<RequestRecord> = Vec::new();
    let mut next_arrival = 0usize;
    let expected = trace.len();

    // request id -> output_len lookup for active prefill batch
    let out_len = |id: u64, trace: &[Request]| trace[id as usize].output_len;

    while records.len() < expected {
        let now = sim.now();
        if now > opts.max_virtual_time {
            panic!(
                "virtual time cap exceeded: {} records of {} done at t={now}",
                records.len(),
                expected
            );
        }

        // 1. Admit arrivals.
        while next_arrival < trace.len() && trace[next_arrival].arrival <= now {
            let r = &trace[next_arrival];
            waiting.push(PrefillReq {
                id: r.id,
                arrival: r.arrival,
                input_len: r.input_len,
                output_len: r.output_len,
            });
            next_arrival += 1;
        }

        // 2. Prefill engine cycle (only at group boundaries).
        if prefill_inflight == 0 {
            // 2a. Complete a finished batch.
            let finished = active_prefill
                .as_ref()
                .map(|b| b.layers_done >= total_layers)
                .unwrap_or(false);
            if finished {
                let b = active_prefill.take().unwrap();
                for r in &b.reqs {
                    if r.output_len <= 1 {
                        // single-token request: done at prefill.
                        records.push(RequestRecord {
                            id: r.id,
                            arrival: r.arrival,
                            input_len: r.input_len,
                            output_len: r.output_len,
                            first_token_time: now,
                            finish_time: now,
                            prefill_start: b.started_at,
                        });
                        kv.release(r.id).expect("kv release");
                    } else {
                        pending_join.push(ActiveDecode {
                            st: DecodeReqState {
                                id: r.id,
                                input_len: r.input_len,
                                ctx_len: r.input_len,
                                tokens_out: 1,
                                output_len: r.output_len,
                                decode_elapsed: 0.0,
                            },
                            arrival: r.arrival,
                            prefill_start: b.started_at,
                            first_token_time: now,
                            last_token_time: now,
                        });
                    }
                }
            }

            // 2b. Form a new batch if idle.
            if active_prefill.is_none() && !waiting.is_empty() {
                // urgency order (Algorithm 1 line 7)
                if opts.features.reorder {
                    let mut st = snapshot(
                        now,
                        &active_prefill,
                        &decode,
                        &waiting,
                        rm.partition(),
                        total_layers,
                    );
                    sched.reorder_waiting(&mut st);
                    waiting = st.waiting.clone();
                }
                let mut batch_reqs: Vec<PrefillReq> = Vec::new();
                let mut tokens = 0usize;
                let mut i = 0;
                while i < waiting.len() {
                    let r = &waiting[i];
                    let reserve = r.input_len + r.output_len;
                    // TTFT-first admission: a prompt runs alone unless it
                    // and its batch-mates all fit under the small-prompt
                    // threshold (batching only to amortize launches).
                    let fits_policy = batch_reqs.is_empty()
                        || tokens + r.input_len <= cfg.prefill_batch_tokens;
                    if fits_policy
                        && tokens + r.input_len <= cfg.max_prefill_tokens
                        && kv.can_grow(r.id, reserve)
                    {
                        kv.grow(r.id, reserve).expect("kv reserve");
                        tokens += r.input_len;
                        batch_reqs.push(waiting.remove(i));
                    } else if batch_reqs.is_empty() && decode.is_empty() && pending_join.is_empty()
                    {
                        // nothing running that could free memory: the
                        // request can never fit — fail it loudly.
                        panic!(
                            "request {} needs {} KV tokens but pool holds {}",
                            r.id,
                            reserve,
                            kv.capacity_tokens()
                        );
                    } else {
                        i += 1;
                    }
                }
                if !batch_reqs.is_empty() {
                    active_prefill = Some(PrefillBatch::new(batch_reqs, now));
                }
            }

            // 2c. Launch the next layer group under a fresh decision.
            if let Some(b) = &active_prefill {
                let mut st = snapshot(now, &active_prefill, &decode, &waiting, rm.partition(), total_layers);
                let d = decide(&sched, &mut st, &opts.features, &cfg);
                apply_decision(&mut rm, &d, &mut paused_decode, &mut decode_pauses);
                if opts.record_timeline {
                    push_sample(&mut timeline, &mut sim, &rm, b.n_tokens, decode.len(), waiting.len());
                }
                let layers = cfg
                    .prefill_layer_group
                    .max(1)
                    .min(total_layers - b.layers_done);
                let shape = PhaseShape { tokens: b.n_tokens, context: 0 };
                let stream = rm.prefill_stream();
                let mut n = 0;
                for _ in 0..layers {
                    for k in prefill_layer_kernels(&cfg.model, shape) {
                        sim.submit(stream, k);
                        n += 1;
                    }
                }
                prefill_inflight = n;
                group_size = layers;
            }
        }

        // 3. Decode engine cycle (only at iteration boundaries).
        if decode_inflight == 0 {
            // 3a. Join migrated requests.
            while decode.len() < cfg.max_decode_batch && !pending_join.is_empty() {
                decode.push(pending_join.remove(0));
            }
            // 3b. Launch an iteration.
            if !decode.is_empty() && !paused_decode {
                if active_prefill.is_none() {
                    // decode-only: take the whole GPU.
                    let mut st = snapshot(now, &active_prefill, &decode, &waiting, rm.partition(), total_layers);
                    let d = decide(&sched, &mut st, &opts.features, &cfg);
                    apply_decision(&mut rm, &d, &mut paused_decode, &mut decode_pauses);
                }
                let bs = decode.len();
                let cl = (decode.iter().map(|d| d.st.ctx_len).sum::<usize>() / bs).max(1);
                let stream = rm.decode_stream();
                let mut n = 0;
                for k in decode_all_layers(&cfg.model, PhaseShape { tokens: bs, context: cl }) {
                    sim.submit(stream, k);
                    n += 1;
                }
                decode_inflight = n;
                decode_iter_start = now;
                decode_iter_bs = bs;
            }
        }

        // 4. Advance virtual time.
        if sim.idle() {
            if next_arrival < trace.len() {
                let dt = (trace[next_arrival].arrival - now).max(0.0) + 1e-9;
                sim.run_for(dt);
                continue;
            } else if records.len() < expected
                && active_prefill.is_none()
                && decode.is_empty()
                && pending_join.is_empty()
                && waiting.is_empty()
            {
                unreachable!("no work left but {} records missing", expected - records.len());
            } else if paused_decode {
                // nothing in flight because decode is paused and prefill
                // just finished — unpause and loop.
                paused_decode = false;
                continue;
            } else {
                continue;
            }
        }
        sim.step();

        // 5. Process completions.
        for c in sim.take_completions() {
            if rm.is_prefill_stream(c.stream) {
                prefill_inflight -= 1;
                if prefill_inflight == 0 {
                    if let Some(b) = &mut active_prefill {
                        b.layers_done += group_size;
                    }
                    // prefill group boundary wakes a paused decode.
                    paused_decode = false;
                }
            } else {
                decode_inflight -= 1;
                if decode_inflight == 0 {
                    let _ = decode_iter_start;
                    debug_assert_eq!(decode_iter_bs, decode.len());
                    let token_time = sim.now();
                    let mut i = 0;
                    while i < decode.len() {
                        let d = &mut decode[i];
                        d.st.tokens_out += 1;
                        d.st.ctx_len += 1;
                        d.st.decode_elapsed += token_time - d.last_token_time;
                        d.last_token_time = token_time;
                        if d.st.finished() {
                            let d = decode.remove(i);
                            records.push(RequestRecord {
                                id: d.st.id,
                                arrival: d.arrival,
                                input_len: d.st.input_len,
                                output_len: out_len(d.st.id, trace),
                                first_token_time: d.first_token_time,
                                finish_time: sim.now(),
                                prefill_start: d.prefill_start,
                            });
                            kv.release(d.st.id).expect("kv release at finish");
                        } else {
                            i += 1;
                        }
                    }
                }
            }
        }
    }

    let util = sim.total_util();
    EngineOutput {
        records,
        timeline,
        reconfigs: rm.reconfig_count(),
        decode_pauses,
        total_flops: util.flops,
        total_bytes: util.bytes,
        virtual_duration: sim.now(),
        peak_kv_blocks: kv.peak_used_blocks(),
    }
}

/// Run the scheduler, then apply the feature mask: fixed partitions
/// override the searched one; disabled pausing clears pause requests.
fn decide(
    sched: &SloScheduler,
    st: &mut SystemState,
    features: &Features,
    cfg: &ServingConfig,
) -> Decision {
    let mut d = sched.schedule(st);
    if !features.dynamic_partition {
        let pm = features
            .fixed_prefill_sms
            .unwrap_or(cfg.gpu.num_sms)
            .min(cfg.gpu.num_sms);
        // §4.4: fixed configurations pin prefill's quota and let decode
        // use the whole GPU (overlapping masks).
        d.partition = Partition {
            prefill_sms: pm,
            decode_sms: cfg.gpu.num_sms,
        };
    }
    if !features.pause {
        d.pause_decode = false;
    }
    d
}

fn snapshot(
    now: f64,
    prefill: &Option<PrefillBatch>,
    decode: &[ActiveDecode],
    waiting: &[PrefillReq],
    partition: Partition,
    total_layers: usize,
) -> SystemState {
    SystemState {
        now,
        prefill: prefill.clone(),
        decode: decode.iter().map(|d| d.st.clone()).collect(),
        waiting: waiting.to_vec(),
        partition,
        total_layers,
    }
}

fn apply_decision(
    rm: &mut ResourceManager,
    d: &Decision,
    paused: &mut bool,
    pauses: &mut u64,
) {
    rm.reconfigure(d.partition);
    if d.pause_decode && !*paused {
        *paused = true;
        *pauses += 1;
    } else if !d.pause_decode {
        *paused = false;
    }
}

fn push_sample(
    timeline: &mut Timeline,
    sim: &mut Simulator,
    rm: &ResourceManager,
    prefill_tokens: usize,
    decode_batch: usize,
    waiting: usize,
) {
    let w = sim.take_util_window();
    let gpu = sim.gpu().clone();
    timeline.push(TimelineSample {
        t: sim.now(),
        prefill_sms: rm.partition().prefill_sms,
        decode_sms: rm.partition().decode_sms,
        prefill_tokens,
        decode_batch,
        waiting,
        compute_util: w.compute_util(&gpu),
        bandwidth_util: w.bandwidth_util(&gpu),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec, SloSpec};
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn quick_setup() -> (ServingConfig, PerfModel, GroundTruth) {
        let cfg = ServingConfig {
            slo: SloSpec::sharegpt(),
            ..ServingConfig::default()
        };
        let gt = GroundTruth::new(GpuSpec::a100());
        // analytical model is enough for engine-mechanics tests
        let perf = PerfModel::analytical(cfg.gpu.clone(), ModelSpec::llama31_8b());
        (cfg, perf, gt)
    }

    #[test]
    fn serves_all_requests() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 30, 42);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert_eq!(out.records.len(), 30);
        // every record is causally consistent
        for r in &out.records {
            assert!(r.prefill_start >= r.arrival - 1e-9, "req {}", r.id);
            assert!(r.first_token_time >= r.prefill_start);
            assert!(r.finish_time >= r.first_token_time);
        }
    }

    #[test]
    fn unique_ids_and_kv_drained() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 40, 7);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        let mut ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40);
        assert!(out.peak_kv_blocks > 0);
    }

    #[test]
    fn throughput_and_latency_sane() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 60, 3);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        assert!(s.mean_ttft > 0.0 && s.mean_ttft < 10.0, "ttft {}", s.mean_ttft);
        assert!(s.mean_tpot > 0.001 && s.mean_tpot < 0.5, "tpot {}", s.mean_tpot);
        assert!(s.throughput_tok_s > 10.0, "thpt {}", s.throughput_tok_s);
    }

    #[test]
    fn reconfigures_under_load() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::azure_code(), 6.0, 40, 11);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert!(out.reconfigs > 2, "reconfigs {}", out.reconfigs);
    }

    #[test]
    fn timeline_recorded_when_enabled() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 15, 5);
        let opts = SimEngineOptions {
            record_timeline: true,
            ..Default::default()
        };
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &opts);
        assert!(out.timeline.len() > 10);
        // monotone in time
        let ts = out.timeline.samples();
        for w in ts.windows(2) {
            assert!(w[0].t <= w[1].t);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 20, 9);
        let a = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        let b = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert_eq!(a.records, b.records);
        assert_eq!(a.reconfigs, b.reconfigs);
    }

    #[test]
    fn single_token_outputs_finish_at_prefill() {
        let (cfg, perf, gt) = quick_setup();
        let trace = vec![Request { id: 0, arrival: 0.0, input_len: 512, output_len: 1 }];
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert_eq!(out.records.len(), 1);
        assert_eq!(out.records[0].first_token_time, out.records[0].finish_time);
    }
}
