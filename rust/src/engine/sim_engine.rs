//! Bullet's serving policy on the shared serving core: concurrent
//! prefill and decode with dynamic SM partitioning.
//!
//! The virtual-clock event loop, admission, KV accounting and record
//! emission live in [`crate::engine::core`]; this module contributes
//! only Bullet's decisions ([`BulletPolicy`]):
//! - the prefill engine launches one *layer group* at a time and makes a
//!   scheduling decision at every group boundary (§3.3.1);
//! - the decode engine launches whole iterations (CUDA-graph analog) and
//!   decides before each one;
//! - a decode *pause* skips the next decode iteration, waking at the next
//!   prefill group boundary (§3.4.2-②);
//! - prefill→decode migration is copy-free through the shared KV pool;
//!   requests join the decode batch at the next iteration boundary;
//! - KV capacity is reserved for input+output at prefill admission, so a
//!   running request can never deadlock the pool mid-decode (documented
//!   deviation: the paper allocates decode blocks on demand).

use crate::config::ServingConfig;
use crate::engine::core::{CoreOptions, EngineCore, Lane, ServingPolicy};
use crate::gpu::roofline::GroundTruth;
use crate::model::phases::{decode_all_layers, prefill_layer_kernels, PhaseShape};
use crate::perf::{OnlineCalibrator, PerfModel, PerfPredictor};
use crate::resource::Partition;
use crate::sched::{Decision, PrefillBatch, PrefillReq, SloScheduler};
use crate::workload::Request;

pub use crate::engine::core::EngineOutput;

/// Feature switches: the full system runs with everything on; the
/// Fig. 13/14 baselines disable individual mechanisms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Features {
    /// Dynamic SM partitioning (off ⇒ both phases use fixed masks).
    pub dynamic_partition: bool,
    /// SLO-slack reordering of the waiting queue.
    pub reorder: bool,
    /// Temporary decode pausing under TTFT pressure.
    pub pause: bool,
    /// With `dynamic_partition = false`: prefill's fixed SM count
    /// (`None` ⇒ whole GPU).  Decode always gets the whole GPU in the
    /// fixed configurations, as in the paper's sensitivity study (§4.4).
    pub fixed_prefill_sms: Option<usize>,
}

impl Default for Features {
    fn default() -> Self {
        Features {
            dynamic_partition: true,
            reorder: true,
            pause: true,
            fixed_prefill_sms: None,
        }
    }
}

impl Features {
    /// The "Naive" ablation: concurrency only.
    pub fn naive() -> Features {
        Features {
            dynamic_partition: false,
            reorder: false,
            pause: false,
            fixed_prefill_sms: None,
        }
    }

    /// "w/Partition": resource provisioning without the SLO scheduler.
    pub fn partition_only() -> Features {
        Features {
            dynamic_partition: true,
            reorder: false,
            pause: false,
            fixed_prefill_sms: None,
        }
    }

    /// "w/Scheduler": reordering + delayed decode, no partitioning.
    pub fn scheduler_only() -> Features {
        Features {
            dynamic_partition: false,
            reorder: true,
            pause: true,
            fixed_prefill_sms: None,
        }
    }

    /// Fixed prefill quota (MuxServe-style / Fig. 13 sensitivity points).
    pub fn fixed(prefill_sms: usize) -> Features {
        Features {
            dynamic_partition: false,
            reorder: false,
            pause: false,
            fixed_prefill_sms: Some(prefill_sms),
        }
    }
}

/// Engine options.
#[derive(Debug, Clone)]
pub struct SimEngineOptions {
    pub seed: u64,
    /// Record a timeline sample at every scheduling decision.
    pub record_timeline: bool,
    /// Hard cap on virtual time (safety against pathological configs).
    pub max_virtual_time: f64,
    pub features: Features,
}

impl Default for SimEngineOptions {
    fn default() -> Self {
        SimEngineOptions {
            seed: 0xB17,
            record_timeline: false,
            max_virtual_time: 50_000.0,
            features: Features::default(),
        }
    }
}

impl SimEngineOptions {
    fn core_options(&self) -> CoreOptions {
        CoreOptions {
            seed: self.seed,
            record_timeline: self.record_timeline,
            max_virtual_time: self.max_virtual_time,
        }
    }
}

/// Shape of the prefill layer group currently in flight — what the
/// scheduler predicted against at launch, replayed against the observed
/// duration at the drain boundary (the calibration feedback loop).
#[derive(Debug, Clone, Copy)]
struct PrefillLaunch {
    sl: usize,
    ctx: usize,
    pm: usize,
    contended: bool,
    layers: usize,
}

/// Shape of the decode iteration in flight.
#[derive(Debug, Clone, Copy)]
struct DecodeLaunch {
    bs: usize,
    cl: usize,
    dm: usize,
    contended: bool,
}

/// Bullet's decision logic (Algorithm 1 + §3.4 resource management),
/// expressed as a [`ServingPolicy`] over the shared serving core.
///
/// The scheduler consults an [`OnlineCalibrator`] (the [`PerfPredictor`]
/// trait, never the concrete model): with `cfg.calibration.enabled` the
/// policy feeds every lane-drain boundary back as a prediction-residual
/// sample, closing the §3.2 loop at runtime; disabled, the calibrator is
/// a bitwise pass-through to the offline-profiled model.
pub struct BulletPolicy {
    sched: SloScheduler<OnlineCalibrator>,
    features: Features,
    /// The running prefill batch (layer-group progress is policy state;
    /// the core only sees queued and decoding requests).
    active_prefill: Option<PrefillBatch>,
    /// Layers launched in the current group.
    group_size: usize,
    paused_decode: bool,
    /// In-flight launch shapes, consumed at the matching drain.
    prefill_launch: Option<PrefillLaunch>,
    decode_launch: Option<DecodeLaunch>,
}

impl BulletPolicy {
    pub fn new(cfg: &ServingConfig, perf: &PerfModel, features: Features) -> BulletPolicy {
        let mut calibrator = OnlineCalibrator::new(perf.clone(), cfg.calibration.clone());
        calibrator.set_memo(cfg.memo);
        BulletPolicy {
            sched: SloScheduler::new(cfg.clone(), calibrator),
            features,
            active_prefill: None,
            group_size: 0,
            paused_decode: false,
            prefill_launch: None,
            decode_launch: None,
        }
    }

    /// Run the scheduler, then apply the feature mask: fixed partitions
    /// override the searched one; disabled pausing clears pause requests.
    fn decide(&self, core: &EngineCore) -> Decision {
        let mut st = core.snapshot(&self.active_prefill);
        let mut d = self.sched.schedule(&mut st);
        if !self.features.dynamic_partition {
            let cfg = &core.cfg;
            let pm = self
                .features
                .fixed_prefill_sms
                .unwrap_or(cfg.gpu.num_sms)
                .min(cfg.gpu.num_sms);
            // §4.4: fixed configurations pin prefill's quota and let decode
            // use the whole GPU (overlapping masks).
            d.partition = Partition {
                prefill_sms: pm,
                decode_sms: cfg.gpu.num_sms,
            };
        }
        if !self.features.pause {
            d.pause_decode = false;
        }
        d
    }

    fn apply(&mut self, d: &Decision, core: &mut EngineCore) {
        core.rm.reconfigure(d.partition);
        if d.pause_decode && !self.paused_decode {
            self.paused_decode = true;
            core.stats.decode_pauses += 1;
        } else if !d.pause_decode {
            self.paused_decode = false;
        }
    }

    /// Prefill-engine cycle: complete the finished batch, form a new one
    /// (urgency-ordered, KV-reserved), launch the next layer group under
    /// a fresh scheduling decision.
    fn prefill_cycle(&mut self, core: &mut EngineCore) {
        let now = core.now();
        let total_layers = core.cfg.model.n_layers;

        // Complete a finished batch: migrate members to decode.
        let finished = self
            .active_prefill
            .as_ref()
            .map(|b| b.layers_done >= total_layers)
            .unwrap_or(false);
        if finished {
            let b = self.active_prefill.take().unwrap();
            for r in &b.reqs {
                core.finish_prefill(r.clone(), b.started_at);
            }
        }

        // Form a new batch if idle.
        if self.active_prefill.is_none() && !core.waiting.is_empty() {
            // urgency order (Algorithm 1 line 7)
            if self.features.reorder {
                core.waiting.sort_by(|a, b| {
                    self.sched
                        .ttft_slack(&a.req, now)
                        .total_cmp(&self.sched.ttft_slack(&b.req, now))
                });
            }
            let mut batch_reqs: Vec<PrefillReq> = Vec::new();
            let mut tokens = 0usize;
            let mut i = 0;
            while i < core.waiting.len() {
                let r = core.waiting[i].req.clone();
                // charge only the uncached suffix: prefix-cached tokens
                // are already resident (adopted at admission)
                let suffix = r.input_len - r.cached_len;
                let reserve = r.input_len + r.output_len - r.cached_len;
                // TTFT-first admission: a prompt runs alone unless it
                // and its batch-mates all fit under the small-prompt
                // threshold (batching only to amortize launches).
                let fits_policy = batch_reqs.is_empty()
                    || tokens + suffix <= core.cfg.prefill_batch_tokens;
                if fits_policy
                    && tokens + suffix <= core.cfg.max_prefill_tokens
                    && core.kv_room(r.id, reserve)
                {
                    core.kv.grow(r.id, reserve).expect("kv reserve");
                    tokens += suffix;
                    core.waiting.remove(i);
                    batch_reqs.push(r);
                } else if batch_reqs.is_empty()
                    && core.decode.is_empty()
                    && core.pending_join.is_empty()
                {
                    // nothing running that could free memory (and
                    // `kv_room` already evicted every reclaimable cached
                    // block): the request can never fit — fail loudly.
                    panic!(
                        "request {} needs {} KV tokens but pool holds {}",
                        r.id,
                        reserve,
                        core.kv.capacity_tokens()
                    );
                } else {
                    i += 1;
                }
            }
            if !batch_reqs.is_empty() {
                self.active_prefill = Some(PrefillBatch::new(batch_reqs, now));
            }
        }

        // Launch the next layer group under a fresh decision.
        if self.active_prefill.is_some() {
            let d = self.decide(core);
            self.apply(&d, core);
            let b = self.active_prefill.as_ref().unwrap();
            let (n_tokens, layers_done, ctx_cached) = (b.n_tokens, b.layers_done, b.ctx_cached);
            core.sample_timeline(n_tokens);
            let layers = core
                .cfg
                .prefill_layer_group
                .max(1)
                .min(total_layers - layers_done);
            // prefix-cached tokens are not recomputed, but the suffix's
            // attention reads their KV — the same reload physics as a
            // chunked continuation
            let shape = PhaseShape { tokens: n_tokens, context: ctx_cached };
            let mut kernels = Vec::new();
            for _ in 0..layers {
                kernels.extend(prefill_layer_kernels(&core.cfg.model, shape));
            }
            let stream = core.rm.prefill_stream();
            core.submit(Lane::Prefill, stream, kernels);
            self.group_size = layers;
            self.prefill_launch = Some(PrefillLaunch {
                sl: n_tokens,
                ctx: ctx_cached,
                pm: core.rm.partition().prefill_sms,
                contended: !core.decode.is_empty(),
                layers,
            });
        }
    }

    /// Decode-engine cycle: join migrated requests, launch an iteration.
    fn decode_cycle(&mut self, core: &mut EngineCore) {
        core.join_pending(core.cfg.max_decode_batch);
        if core.decode.is_empty() || self.paused_decode {
            return;
        }
        if self.active_prefill.is_none() {
            // decode-only: take the whole GPU.
            let d = self.decide(core);
            self.apply(&d, core);
        }
        let bs = core.decode.len();
        let cl = (core.decode.iter().map(|d| d.st.ctx_len).sum::<usize>() / bs).max(1);
        let kernels = decode_all_layers(&core.cfg.model, PhaseShape { tokens: bs, context: cl });
        let stream = core.rm.decode_stream();
        core.submit(Lane::Decode, stream, kernels);
        self.decode_launch = Some(DecodeLaunch {
            bs,
            cl,
            dm: core.rm.partition().decode_sms,
            contended: self.active_prefill.is_some(),
        });
    }
}

impl ServingPolicy for BulletPolicy {
    /// Mirrors `System::label()` for the bullet-family feature masks, so
    /// cluster tables attribute ablation/fixed-quota runs correctly.
    fn label(&self) -> String {
        let f = &self.features;
        if let Some(n) = f.fixed_prefill_sms {
            return format!("SM-{n}");
        }
        match (f.dynamic_partition, f.reorder || f.pause) {
            (true, true) => "Bullet".into(),
            (true, false) => "w/Partition".into(),
            (false, true) => "w/Scheduler".into(),
            (false, false) => "Naive".into(),
        }
    }

    fn plan(&mut self, core: &mut EngineCore) {
        // Prefill decisions happen at layer-group boundaries, decode
        // decisions at iteration boundaries — the lanes are decoupled.
        if core.lane_idle(Lane::Prefill) {
            self.prefill_cycle(core);
        }
        if core.lane_idle(Lane::Decode) {
            self.decode_cycle(core);
        }
        // keep the memo observability counters current (never parity-
        // compared; syncing costs one Copy)
        core.stats.predict_memo = self.sched.perf.memo_counters();
    }

    fn on_drain(&mut self, lane: Lane, core: &mut EngineCore) {
        // Close the calibration loop: the drain instant is the launched
        // group's completion, so `lane_busy_span` is the OBSERVED
        // duration of the shape the scheduler predicted at launch.
        // (No-op samples when calibration is disabled.)
        match lane {
            Lane::Prefill => {
                if let Some(l) = self.prefill_launch.take() {
                    let observed = core.lane_busy_span(Lane::Prefill);
                    let fed = self
                        .sched
                        .perf
                        .observe_prefill(l.sl, l.ctx, l.pm, l.contended, l.layers, observed);
                    if fed.is_some() {
                        core.note_calibration(self.sched.perf.stats());
                    }
                }
                if let Some(b) = &mut self.active_prefill {
                    b.layers_done += self.group_size;
                }
                // prefill group boundary wakes a paused decode.
                self.paused_decode = false;
            }
            Lane::Decode => {
                if let Some(l) = self.decode_launch.take() {
                    let observed = core.lane_busy_span(Lane::Decode);
                    let fed = self
                        .sched
                        .perf
                        .observe_decode(l.bs, l.cl, l.dm, l.contended, observed);
                    if fed.is_some() {
                        core.note_calibration(self.sched.perf.stats());
                    }
                }
                core.advance_decode_token()
            }
        }
        core.stats.predict_memo = self.sched.perf.memo_counters();
    }

    fn on_stall(&mut self, _core: &mut EngineCore) -> bool {
        // nothing in flight because decode is paused and prefill just
        // finished — unpause and loop.
        if self.paused_decode {
            self.paused_decode = false;
            true
        } else {
            false
        }
    }

    fn has_private_work(&self) -> bool {
        self.active_prefill.is_some()
    }

    fn private_backlog_tokens(&self) -> usize {
        match &self.active_prefill {
            None => 0,
            Some(b) => {
                let total = self.sched.cfg.model.n_layers.max(1);
                let left = total.saturating_sub(b.layers_done);
                b.n_tokens * left / total
            }
        }
    }

    fn predictor(&self) -> Option<&dyn PerfPredictor> {
        Some(&self.sched.perf)
    }

    fn reprofile(&mut self) -> bool {
        if !self.sched.perf.enabled() {
            return false;
        }
        self.sched.perf.reprofile();
        true
    }
}

/// Serve `trace` with the full Bullet engine; returns per-request records.
/// (Thin wrapper over [`EngineCore`] + [`BulletPolicy`] so existing
/// callers, benches and examples keep compiling unchanged.)
pub fn serve_bullet(
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    opts: &SimEngineOptions,
) -> EngineOutput {
    let mut core = EngineCore::new(cfg.clone(), gt.clone(), trace.to_vec(), &opts.core_options());
    let mut policy = BulletPolicy::new(cfg, perf, opts.features);
    core.run(&mut policy);
    core.into_output()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec, SloSpec};
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn quick_setup() -> (ServingConfig, PerfModel, GroundTruth) {
        let cfg = ServingConfig {
            slo: SloSpec::sharegpt(),
            ..ServingConfig::default()
        };
        let gt = GroundTruth::new(GpuSpec::a100());
        // analytical model is enough for engine-mechanics tests
        let perf = PerfModel::analytical(cfg.gpu.clone(), ModelSpec::llama31_8b());
        (cfg, perf, gt)
    }

    #[test]
    fn serves_all_requests() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 30, 42);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert_eq!(out.records.len(), 30);
        // every record is causally consistent
        for r in &out.records {
            assert!(r.prefill_start >= r.arrival - 1e-9, "req {}", r.id);
            assert!(r.first_token_time >= r.prefill_start);
            assert!(r.finish_time >= r.first_token_time);
        }
    }

    #[test]
    fn unique_ids_and_kv_drained() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 40, 7);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        let mut ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40);
        assert!(out.peak_kv_blocks > 0);
    }

    #[test]
    fn throughput_and_latency_sane() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 60, 3);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        assert!(s.mean_ttft > 0.0 && s.mean_ttft < 10.0, "ttft {}", s.mean_ttft);
        assert!(s.mean_tpot > 0.001 && s.mean_tpot < 0.5, "tpot {}", s.mean_tpot);
        assert!(s.throughput_tok_s > 10.0, "thpt {}", s.throughput_tok_s);
    }

    #[test]
    fn reconfigures_under_load() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::azure_code(), 6.0, 40, 11);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert!(out.reconfigs > 2, "reconfigs {}", out.reconfigs);
    }

    #[test]
    fn timeline_recorded_when_enabled() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 15, 5);
        let opts = SimEngineOptions {
            record_timeline: true,
            ..Default::default()
        };
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &opts);
        assert!(out.timeline.len() > 10);
        // monotone in time
        let ts = out.timeline.samples();
        for w in ts.windows(2) {
            assert!(w[0].t <= w[1].t);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 20, 9);
        let a = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        let b = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert_eq!(a.records, b.records);
        assert_eq!(a.reconfigs, b.reconfigs);
    }

    #[test]
    fn calibration_off_leaves_counters_at_identity() {
        let (cfg, perf, gt) = quick_setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 10, 13);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert_eq!(out.calibration.samples, 0);
        assert_eq!(out.calibration.drift_events, 0);
        assert_eq!(out.calibration.slowdown, 1.0);
    }

    #[test]
    fn calibration_on_ingests_lane_drain_samples() {
        use crate::config::CalibrationConfig;
        let (mut cfg, perf, gt) = quick_setup();
        cfg.calibration = CalibrationConfig::on();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 15, 13);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert_eq!(out.records.len(), 15);
        assert!(out.calibration.samples > 10, "{:?}", out.calibration);
        assert!(out.calibration.slowdown.is_finite() && out.calibration.slowdown > 0.0);
        assert!(out.calibration.mean_abs_residual().is_finite());
    }

    #[test]
    fn calibrated_runs_are_deterministic() {
        use crate::config::CalibrationConfig;
        let (mut cfg, perf, gt) = quick_setup();
        cfg.calibration = CalibrationConfig::on();
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 20, 9);
        let a = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        let b = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert_eq!(a.records, b.records);
        assert_eq!(a.calibration, b.calibration);
    }

    #[test]
    fn single_token_outputs_finish_at_prefill() {
        let (cfg, perf, gt) = quick_setup();
        let trace = vec![Request { id: 0, arrival: 0.0, input_len: 512, output_len: 1, ..Default::default() }];
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        assert_eq!(out.records.len(), 1);
        assert_eq!(out.records[0].first_token_time, out.records[0].finish_time);
    }
}
