//! Shared metadata buffer (§3.5.2): the decentralized status board the
//! prefill and decode engines read/write instead of synchronizing through
//! a central controller.
//!
//! The paper implements this as OS shared memory between two processes
//! plus control bits for availability.  Here the engines are threads, so
//! the buffer is a lock-minimal `Arc<MetadataBuffer>`: hot counters are
//! atomics; the request-handoff queue (prefill → decode migration) is a
//! short mutex-protected ring.  Every write is wait-free for readers of
//! the atomic fields.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// A request handed from the prefill engine to the decode engine —
/// copy-free: KV stays in the shared pool, only indices travel.
#[derive(Debug, Clone, PartialEq)]
pub struct Handoff {
    pub req_id: u64,
    pub seq_id: u64,
    pub input_len: usize,
    pub output_len: usize,
    pub first_token: i32,
    /// Absolute time the first token was produced.
    pub first_token_time: f64,
    pub arrival: f64,
    pub prefill_start: f64,
    /// Lifecycle carried across the migration: the decode engine honors
    /// the client disconnect and the deadline at iteration boundaries.
    pub cancel_at: Option<f64>,
    pub deadline: Option<f64>,
}

/// The shared status board.
#[derive(Debug, Default)]
pub struct MetadataBuffer {
    /// Decode engine's current batch size (read by the prefill scheduler).
    pub decode_batch: AtomicUsize,
    /// Sum of context lengths in the decode batch.
    pub decode_ctx_sum: AtomicUsize,
    /// Most recent decode iteration latency, microseconds.
    pub decode_iter_us: AtomicU64,
    /// Requests waiting for prefill (read by the decode scheduler).
    pub waiting: AtomicUsize,
    /// Tokens in the active prefill batch (0 = prefill idle).
    pub prefill_tokens: AtomicUsize,
    /// Prefill layers completed on the active batch.
    pub prefill_layers_done: AtomicUsize,
    /// Engines set this to request shutdown.
    pub shutdown: AtomicBool,
    /// Prefill→decode migration queue ("request metadata sent to buffer").
    handoffs: Mutex<VecDeque<Handoff>>,
}

impl MetadataBuffer {
    pub fn new() -> MetadataBuffer {
        MetadataBuffer::default()
    }

    /// Prefill side: publish a finished request for the decode engine.
    pub fn push_handoff(&self, h: Handoff) {
        self.handoffs.lock().unwrap().push_back(h);
    }

    /// Decode side: drain pending migrations (called at iteration
    /// boundaries, like the paper's step-2 metadata fetch).
    pub fn drain_handoffs(&self, max: usize) -> Vec<Handoff> {
        let mut q = self.handoffs.lock().unwrap();
        let n = q.len().min(max);
        q.drain(..n).collect()
    }

    pub fn pending_handoffs(&self) -> usize {
        self.handoffs.lock().unwrap().len()
    }

    /// Decode engine heartbeat: publish batch status.
    pub fn publish_decode(&self, batch: usize, ctx_sum: usize, iter_s: f64) {
        self.decode_batch.store(batch, Ordering::Release);
        self.decode_ctx_sum.store(ctx_sum, Ordering::Release);
        self.decode_iter_us
            .store((iter_s * 1e6) as u64, Ordering::Release);
    }

    /// Prefill engine heartbeat.
    pub fn publish_prefill(&self, tokens: usize, layers_done: usize, waiting: usize) {
        self.prefill_tokens.store(tokens, Ordering::Release);
        self.prefill_layers_done.store(layers_done, Ordering::Release);
        self.waiting.store(waiting, Ordering::Release);
    }

    pub fn snapshot_decode(&self) -> (usize, usize, f64) {
        (
            self.decode_batch.load(Ordering::Acquire),
            self.decode_ctx_sum.load(Ordering::Acquire),
            self.decode_iter_us.load(Ordering::Acquire) as f64 * 1e-6,
        )
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn handoff(id: u64) -> Handoff {
        Handoff {
            req_id: id,
            seq_id: id,
            input_len: 8,
            output_len: 4,
            first_token: 42,
            first_token_time: 1.0,
            arrival: 0.0,
            prefill_start: 0.5,
            cancel_at: None,
            deadline: None,
        }
    }

    #[test]
    fn handoff_fifo() {
        let m = MetadataBuffer::new();
        m.push_handoff(handoff(1));
        m.push_handoff(handoff(2));
        m.push_handoff(handoff(3));
        let got = m.drain_handoffs(2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].req_id, 1);
        assert_eq!(m.pending_handoffs(), 1);
    }

    #[test]
    fn publish_snapshot_roundtrip() {
        let m = MetadataBuffer::new();
        m.publish_decode(17, 3400, 0.015);
        let (b, c, t) = m.snapshot_decode();
        assert_eq!(b, 17);
        assert_eq!(c, 3400);
        assert!((t - 0.015).abs() < 1e-6);
    }

    #[test]
    fn cross_thread_visibility() {
        let m = Arc::new(MetadataBuffer::new());
        let m2 = m.clone();
        let th = std::thread::spawn(move || {
            for i in 0..100 {
                m2.push_handoff(handoff(i));
            }
            m2.publish_prefill(128, 7, 3);
            m2.request_shutdown();
        });
        th.join().unwrap();
        assert!(m.is_shutdown());
        assert_eq!(m.pending_handoffs(), 100);
        assert_eq!(m.prefill_tokens.load(Ordering::Acquire), 128);
        assert_eq!(m.prefill_layers_done.load(Ordering::Acquire), 7);
    }
}
