//! Concurrent execution engines (§3.5).
//!
//! Two modes share the scheduling logic:
//! - **sim** (`sim_engine`): a virtual-clock event loop over the GPU
//!   simulator — deterministic, used for every paper experiment at
//!   A100/Llama-8B scale.
//! - **live** (`live_engine`): real prefill/decode threads over the PJRT
//!   runtime with a shared metadata buffer (`metadata`) and the shared KV
//!   pool — proves the decentralized-engines design composes end-to-end
//!   on real compute (examples/serve_real_model.rs).

pub mod live_engine;
pub mod metadata;
pub mod sim_engine;

pub use live_engine::{serve_live, LiveRequest, LiveStats};
pub use sim_engine::{serve_bullet, EngineOutput, SimEngineOptions};
