//! Serving engines (§3.5) on the shared serving core.
//!
//! - **core** (`core`): the harness every system runs on — virtual-clock
//!   event loop, admission, KV accounting, prefill→decode migration,
//!   record emission — parameterized by a [`core::ServingPolicy`].
//! - **sim** (`sim_engine`): Bullet's policy (dynamic SM partitioning +
//!   SLO scheduling) over the simulated GPU — deterministic, used for
//!   every paper experiment at A100/Llama-8B scale.  The chunked-prefill
//!   and NanoFlow baselines are sibling policies in [`crate::baselines`].
//! - **live** (`live_engine`): real prefill/decode threads over the PJRT
//!   runtime with a shared metadata buffer (`metadata`) and the shared KV
//!   pool — proves the decentralized-engines design composes end-to-end
//!   on real compute (examples/serve_real_model.rs).  Live mode consumes
//!   the same [`crate::workload::Request`] as the simulators (prompts
//!   travel index-aligned), lifecycle annotations included.

pub mod core;
pub mod live_engine;
pub mod metadata;
pub mod sim_engine;

pub use self::core::{CoreOptions, CoreStats, EngineCore, EngineOutput, Lane, ServingPolicy};
pub use live_engine::{serve_live, LiveStats};
pub use sim_engine::{serve_bullet, BulletPolicy, Features, SimEngineOptions};
