//! The shared serving core: one virtual-clock event loop for every
//! serving system in the repo.
//!
//! Historically each system (`serve_bullet`, chunked vLLM/SGLang,
//! NanoFlow, the static-partition configurations) was a monolithic loop
//! re-implementing admission, KV accounting, request lifecycle and
//! metrics bookkeeping.  [`EngineCore`] owns all of those *mechanisms*;
//! a [`ServingPolicy`] owns only the *decisions* — what to launch, on
//! which lane, under which SM partition.  A new serving policy is now
//! ~100 lines: implement `plan` (launch kernels at lane boundaries) and
//! `on_drain` (lifecycle effects when a lane's kernels finish), and the
//! harness provides everything else.
//!
//! Mechanisms owned here:
//! - the event loop over the [`Simulator`] (admission → plan → advance →
//!   completions), with idle-time jumps to the next arrival;
//! - the waiting queue ([`PrefillProgress`]) fed from the trace;
//! - KV-pool reserve/release bookkeeping at admission and completion;
//! - the prefix-cache fast path (when `cfg.prefix_cache` is on): at
//!   admission the request's content-hash chain is matched against the
//!   [`PrefixIndex`], the hit blocks are adopted into the KV pool, and
//!   the request is charged only its uncached suffix (`cached_len` /
//!   `PrefillProgress::done`); at prefill completion the prompt's full
//!   blocks are published back to the index.  [`EngineCore::kv_room`] is
//!   the evict-vs-recompute hook policies call under memory pressure;
//! - prefill→decode migration through `pending_join` (copy-free, the
//!   shared-pool semantics of §3.5);
//! - per-token decode advancement and [`RequestRecord`] emission;
//! - timeline sampling and the run-level counters in [`EngineOutput`].
//!
//! Execution model: two *lanes* (prefill, decode) backed by the
//! [`ResourceManager`]'s pre-configured stream palette.  Policies that
//! partition the GPU launch on the palette stream for the current
//! partition; whole-GPU policies use the full-mask streams.  The core
//! tracks in-flight kernels per lane and notifies the policy when a lane
//! drains — per-lane boundaries give Bullet's decoupled engines, while a
//! policy that only plans when *all* lanes are idle gets lock-step
//! (chunked prefill) or barrier-overlap (NanoFlow) semantics for free.
//!
//! Time-jump discipline: when the simulator is idle the pump advances
//! the clock with [`Simulator::advance_idle_to`] — an ABSOLUTE jump —
//! never with a relative `run_for`.  A relative jump makes the landing
//! clock depend on the prior clock in floating point
//! (`a + (t - a) ≠ t` in general), which would make an engine's state a
//! function of how many idle horizons it visited; the absolute form
//! keeps `run_until(t)` on a drained engine equivalent to one clock
//! assignment, which the cluster layer exploits to skip drained
//! replicas entirely.

use crate::config::ServingConfig;
use crate::gateway::stream::StreamChunk;
use crate::gpu::kernel::KernelDesc;
use crate::gpu::roofline::GroundTruth;
use crate::gpu::simulator::{IdleTag, Simulator};
use crate::gpu::stream::StreamId;
use crate::kvcache::prefix::{PrefixIndex, PrefixStats};
use crate::kvcache::{KvPool, BLOCK_TOKENS};
use crate::metrics::timeline::{ScaleEvent, Timeline, TimelineSample};
use crate::metrics::{OutcomeRecord, RequestOutcome, RequestRecord};
use crate::obs::ledger::SmLedger;
use crate::obs::trace::EngineTraceEvent;
use crate::perf::{CalibrationStats, PerfPredictor};
use crate::resource::ResourceManager;
use crate::util::memo::MemoCounters;
use crate::sched::{
    deadline_should_drop, ActiveDecode, DecodeReqState, PrefillBatch, PrefillProgress, PrefillReq,
    SystemState,
};
use crate::workload::Request;
use std::collections::BTreeMap;
use std::sync::mpsc;

/// Per-request prefix bookkeeping between admission and prefill finish.
#[derive(Debug)]
struct PrefixMeta {
    /// The prompt's chained per-block content hashes.
    chain: Vec<u64>,
    /// Leading blocks already published at chunk boundaries.
    published: usize,
}

/// Per-request lifecycle annotations, tracked from admission until the
/// request exits (by any path).  Only requests that carry at least one
/// annotation get an entry, so lifecycle-free traces pay nothing.
#[derive(Debug, Clone, Copy)]
struct LifecycleMeta {
    cancel_at: Option<f64>,
    deadline: Option<f64>,
}

/// The two execution lanes of the serving core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    Prefill = 0,
    Decode = 1,
}

/// Everything a serving run produces.
#[derive(Debug, Clone)]
pub struct EngineOutput {
    pub records: Vec<RequestRecord>,
    /// Terminal events for requests that did NOT complete (cancelled,
    /// expired, lost to a crash).  Always empty for lifecycle-free
    /// traces; `records` and `outcomes` together partition the trace.
    pub outcomes: Vec<OutcomeRecord>,
    pub timeline: Timeline,
    pub reconfigs: u64,
    pub decode_pauses: u64,
    /// Total achieved FLOPs / bytes / SM-seconds (whole run).
    pub total_flops: f64,
    pub total_bytes: f64,
    pub virtual_duration: f64,
    pub peak_kv_blocks: usize,
    /// Blocks still allocated at teardown.  Zero for any run that
    /// completes (every exit path — finish, cancel, expiry, crash —
    /// releases its KV); the leak detector lifecycle tests assert on.
    pub final_kv_blocks: usize,
    /// Prefix-cache counters (all zero with `cfg.prefix_cache` off).
    pub prefix: PrefixStats,
    /// Online-calibration counters (all zero / identity with
    /// `cfg.calibration.enabled` off or a calibration-free policy).
    pub calibration: CalibrationStats,
    /// Fleet-lifecycle events that targeted THIS engine (spawn, retire,
    /// re-profile) — filled by the cluster autoscaler; always empty for
    /// single-GPU and fixed-fleet runs.  The same events also ride
    /// `timeline.events()`.
    pub scale_events: Vec<ScaleEvent>,
    /// Simulator rate-table memo counters (hot-path observability only —
    /// never part of any bit-parity comparison).  The hit rate is the
    /// fraction of steps that reused the cached per-kernel rate table.
    pub rate_memo: MemoCounters,
    /// Calibrated-prediction memo counters from the policy's
    /// [`crate::perf::OnlineCalibrator`] (zero for calibration-free
    /// policies; observability only).
    pub predict_memo: MemoCounters,
    /// SM-second attribution ledger, finalized at teardown: the seven
    /// categories sum to `num_sms × virtual_duration` (tested invariant).
    /// Observability only — excluded from bit-parity comparisons of the
    /// serving outputs, but itself deterministic and parity-checked.
    pub ledger: SmLedger,
    /// Structured engine trace events ([`TraceSpec`]-gated; empty with
    /// tracing off, which is the default).
    ///
    /// [`TraceSpec`]: crate::obs::trace::TraceSpec
    pub trace_events: Vec<EngineTraceEvent>,
}

/// Run-level counters policies may bump.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoreStats {
    pub decode_pauses: u64,
    /// Calibration counters, kept current by calibrating policies at
    /// each observation (the core surfaces them in [`EngineOutput`] and
    /// the timeline).
    pub calib: CalibrationStats,
    /// Calibrated-prediction memo counters, synced by calibrating
    /// policies alongside `calib` (observability only — excluded from
    /// every parity comparison).
    pub predict_memo: MemoCounters,
}

/// Core construction options (engine-agnostic subset of the old
/// `SimEngineOptions`).
#[derive(Debug, Clone)]
pub struct CoreOptions {
    pub seed: u64,
    /// Record a timeline sample at every scheduling decision.
    pub record_timeline: bool,
    /// Hard cap on virtual time (safety against pathological configs).
    pub max_virtual_time: f64,
}

impl Default for CoreOptions {
    fn default() -> Self {
        CoreOptions {
            seed: 0xB17,
            record_timeline: false,
            max_virtual_time: 50_000.0,
        }
    }
}

/// A serving system's decision logic, driven by [`EngineCore`].
///
/// Contract: `plan` is invoked once per loop turn (after admission); it
/// should launch work via [`EngineCore::submit`] only on lanes that are
/// idle ([`EngineCore::lane_idle`]).  `on_drain` fires when a lane's
/// in-flight kernel count returns to zero and is where per-boundary
/// lifecycle effects (layer-group credit, token ticks) belong.
///
/// `Send` is a supertrait so a boxed policy (and with it a whole cluster
/// replica) can move to a simulation worker thread; policies are plain
/// owned state, so this costs implementors nothing.
pub trait ServingPolicy: Send {
    /// Display label for tables and logs.
    fn label(&self) -> String;

    /// Launch work for any lane at a boundary.
    fn plan(&mut self, core: &mut EngineCore);

    /// A lane's in-flight kernels just drained to zero.
    fn on_drain(&mut self, lane: Lane, core: &mut EngineCore);

    /// Nothing is in flight and `plan` declined to launch.  Make progress
    /// if possible (unpause, wait out a memory stall) and return `true`;
    /// returning `false` lets the core jump to the next arrival or flag a
    /// stuck engine.
    fn on_stall(&mut self, core: &mut EngineCore) -> bool {
        let _ = core;
        false
    }

    /// Whether the policy holds work in private state (e.g. an active
    /// prefill batch) that the core cannot see — used to distinguish a
    /// drained system from a wedged one.
    fn has_private_work(&self) -> bool {
        false
    }

    /// Whether the policy currently holds INDICES into `core.waiting`
    /// (e.g. a hybrid chunked batch in flight).  While locked, the core
    /// defers lifecycle removals from the waiting queue — cancelling an
    /// entry would shift the indices under the batch.  Deferred requests
    /// are caught on a later turn (or after prefill, in `pending_join`).
    fn waiting_locked(&self) -> bool {
        false
    }

    /// Prefill tokens held in private state (active batches) — used by
    /// cluster routers to estimate backlog.  Queue backlog is counted by
    /// the core itself.
    fn private_backlog_tokens(&self) -> usize {
        0
    }

    /// The policy's live performance predictor, if it keeps one (Bullet's
    /// online calibrator).  Cluster routers consult this so routing sees
    /// each replica's *calibrated* speed; `None` (the default) falls back
    /// to the shared offline model.
    fn predictor(&self) -> Option<&dyn PerfPredictor> {
        None
    }

    /// Refresh the policy's offline performance grid in place (the
    /// cluster autoscaler's re-profiling action for replicas whose
    /// converged calibrator keeps reporting high residuals).  Returns
    /// whether a refresh happened; calibration-free policies decline.
    fn reprofile(&mut self) -> bool {
        false
    }

    /// SM count the router's prefill probe should price new arrivals
    /// against.  Policies that pin prefill to a fixed SM partition (the
    /// intra-GPU P/D disaggregation baselines) report it here so
    /// slo-slack routing sees the partition, not the whole GPU; `None`
    /// (the default) means prefill can reach every SM eventually —
    /// Bullet repartitions on demand, chunked/NanoFlow run full-GPU —
    /// and the probe uses the replica's total SM count.
    fn probe_prefill_sms(&self) -> Option<usize> {
        None
    }
}

/// The shared serving core (see module docs).
pub struct EngineCore {
    pub cfg: ServingConfig,
    pub sim: Simulator,
    pub rm: ResourceManager,
    pub kv: KvPool,
    /// Content-addressed prefix cache (`None` ⇔ `cfg.prefix_cache` off).
    pub prefix: Option<PrefixIndex>,
    /// Prompt hash chains of admitted-but-unfinished cacheable requests,
    /// plus how many leading blocks chunk boundaries already published
    /// (so each boundary publishes only its delta).
    prefix_meta: BTreeMap<u64, PrefixMeta>,
    /// Admitted-but-not-yet-fully-prefilled requests.
    pub waiting: Vec<PrefillProgress>,
    /// The running decode batch.
    pub decode: Vec<ActiveDecode>,
    /// Finished prefills awaiting a decode-boundary join (copy-free
    /// migration: the KV stays put, only the handle moves).
    pub pending_join: Vec<ActiveDecode>,
    pub records: Vec<RequestRecord>,
    /// Terminal events for non-completing requests (see
    /// [`EngineOutput::outcomes`]).
    pub outcomes: Vec<OutcomeRecord>,
    pub timeline: Timeline,
    pub stats: CoreStats,
    /// Lifecycle annotations of live annotated requests, keyed by id.
    lifecycle: BTreeMap<u64, LifecycleMeta>,
    /// Streaming sinks attached by the gateway, keyed by request id.  A
    /// chunk is sent per produced token and a terminal chunk on every
    /// exit path; an empty map (no gateway) costs one branch per token.
    sinks: BTreeMap<u64, mpsc::Sender<StreamChunk>>,
    trace: Vec<Request>,
    next_arrival: usize,
    inflight: [usize; 2],
    /// Virtual time each lane last went idle→busy (the launch instant of
    /// the in-flight kernel group) — the observation stream's clock: at
    /// the matching drain, `now - lane_started` is the group's measured
    /// duration, which calibrating policies feed back as a
    /// prediction-residual sample.
    lane_started: [f64; 2],
    record_timeline: bool,
    max_virtual_time: f64,
    /// Did any `kv_room` call fail since the top of the current pump
    /// turn?  Feeds the idle-tag heuristic: a stall turn that saw KV
    /// pressure charges its idle span to `KvBlocked`.
    kv_blocked_turn: bool,
    /// `rm.reconfig_count()` snapshot at the top of the current pump
    /// turn — a plan that repartitioned but launched nothing charges its
    /// idle span to `Repartition` (the transition gap).
    reconfigs_seen: u64,
    /// `cfg.trace.enabled` hoisted; false is the bit-identical default.
    trace_enabled: bool,
    trace_buf: Vec<EngineTraceEvent>,
}

impl EngineCore {
    /// Assemble a core over a fresh simulated GPU.  `trace` must be
    /// sorted by arrival time.
    pub fn new(
        cfg: ServingConfig,
        gt: GroundTruth,
        trace: Vec<Request>,
        opts: &CoreOptions,
    ) -> EngineCore {
        debug_assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let mut sim = Simulator::new(gt, opts.seed);
        sim.set_memo(cfg.memo);
        let rm = ResourceManager::new(&mut sim, &cfg.gpu);
        let kv = KvPool::new(cfg.kv_capacity_tokens);
        let prefix = cfg.prefix_cache.then(PrefixIndex::new);
        EngineCore {
            kv,
            prefix,
            prefix_meta: BTreeMap::new(),
            rm,
            sim,
            waiting: Vec::new(),
            decode: Vec::new(),
            pending_join: Vec::new(),
            records: Vec::new(),
            outcomes: Vec::new(),
            timeline: Timeline::new(),
            stats: CoreStats::default(),
            lifecycle: BTreeMap::new(),
            sinks: BTreeMap::new(),
            trace,
            next_arrival: 0,
            inflight: [0, 0],
            lane_started: [0.0, 0.0],
            record_timeline: opts.record_timeline,
            max_virtual_time: opts.max_virtual_time,
            kv_blocked_turn: false,
            reconfigs_seen: 0,
            trace_enabled: cfg.trace.enabled,
            trace_buf: Vec::new(),
            cfg,
        }
    }

    pub fn now(&self) -> f64 {
        self.sim.now()
    }

    pub fn lane_idle(&self, lane: Lane) -> bool {
        self.inflight[lane as usize] == 0
    }

    pub fn all_idle(&self) -> bool {
        self.inflight == [0, 0]
    }

    pub fn record_timeline_enabled(&self) -> bool {
        self.record_timeline
    }

    /// Every request accounted for?  Completions emit records;
    /// cancellations, expiries, and crash losses emit outcomes — the two
    /// streams together must cover the trace.
    pub fn finished(&self) -> bool {
        self.records.len() + self.outcomes.len() >= self.trace.len()
    }

    /// No queued, in-flight, or unadmitted work anywhere in the core.
    /// (The policy may still hold private work — callers combine this
    /// with [`ServingPolicy::has_private_work`].)  On a drained core,
    /// `run_until(t)` reduces to one idle clock jump, so the cluster
    /// layer can skip the call entirely without changing any state the
    /// next jump or push would observe.
    pub fn drained(&self) -> bool {
        self.next_arrival >= self.trace.len()
            && self.waiting.is_empty()
            && self.decode.is_empty()
            && self.pending_join.is_empty()
            && self.sim.idle()
    }

    /// Inject a request after construction (cluster dispatch).  Arrivals
    /// must stay monotone.
    pub fn push_request(&mut self, r: Request) {
        if let Some(last) = self.trace.last() {
            assert!(
                r.arrival >= last.arrival,
                "out-of-order injection: {} after {}",
                r.arrival,
                last.arrival
            );
        }
        self.trace.push(r);
    }

    /// Requests admitted or injected so far.
    pub fn trace_len(&self) -> usize {
        self.trace.len()
    }

    /// Launch kernels on a lane, tracking them for boundary detection.
    pub fn submit(
        &mut self,
        lane: Lane,
        stream: StreamId,
        kernels: impl IntoIterator<Item = KernelDesc>,
    ) {
        let mut n = 0;
        for k in kernels {
            self.sim.submit(stream, k);
            n += 1;
        }
        if n > 0 && self.inflight[lane as usize] == 0 {
            self.lane_started[lane as usize] = self.sim.now();
        }
        if n > 0 && self.trace_enabled {
            self.trace_buf.push(EngineTraceEvent::Launch {
                t: self.sim.now(),
                lane: lane as usize as u8,
                kernels: n,
            });
        }
        self.inflight[lane as usize] += n;
    }

    /// Seconds since the lane's in-flight group launched.  Read in
    /// `on_drain` (the drain instant is the group's completion), this is
    /// the OBSERVED duration matching the policy's prediction at launch
    /// — the raw material of online calibration.
    pub fn lane_busy_span(&self, lane: Lane) -> f64 {
        self.sim.now() - self.lane_started[lane as usize]
    }

    /// Fold a calibration sample's effect into the run counters
    /// (policies call this right after feeding their calibrator).
    pub fn note_calibration(&mut self, stats: CalibrationStats) {
        self.stats.calib = stats;
    }

    /// Move arrivals whose time has come into the waiting queue.  With
    /// the prefix cache on, each cacheable arrival is matched against
    /// the index here (the admission fast path): hit blocks are adopted
    /// into the KV pool and only the uncached suffix remains to prefill.
    pub fn admit_arrivals(&mut self) {
        let now = self.sim.now();
        while self.next_arrival < self.trace.len() && self.trace[self.next_arrival].arrival <= now {
            let (id, arrival, input_len, output_len) = {
                let r = &self.trace[self.next_arrival];
                if r.cancel_at.is_some() || r.deadline.is_some() {
                    self.lifecycle.insert(
                        r.id,
                        LifecycleMeta { cancel_at: r.cancel_at, deadline: r.deadline },
                    );
                }
                (r.id, r.arrival, r.input_len, r.output_len)
            };
            let mut cached = 0usize;
            if self.prefix.is_some() && !self.trace[self.next_arrival].block_hashes.is_empty() {
                // consumed trace entries are never re-read (only
                // `trace[next_arrival..]` is), so move the hashes out
                // instead of cloning — they live on in `prefix_meta`
                // until the prefill completes
                let hashes = std::mem::take(&mut self.trace[self.next_arrival].block_hashes);
                let ix = self.prefix.as_mut().unwrap();
                let blocks = ix.lookup(&hashes, input_len);
                if !blocks.is_empty() {
                    cached = blocks.len() * BLOCK_TOKENS;
                    self.kv.adopt(id, &blocks).expect("prefix adopt at admission");
                }
                self.prefix_meta
                    .insert(id, PrefixMeta { chain: hashes, published: 0 });
            }
            let mut p = PrefillProgress::new(PrefillReq {
                id,
                arrival,
                input_len,
                output_len,
                cached_len: cached,
            });
            p.done = cached;
            self.waiting.push(p);
            self.next_arrival += 1;
        }
    }

    /// Evict-vs-recompute hook for admission-time memory pressure: can
    /// `tokens` more tokens be reserved for `seq_id`?  On pressure the
    /// core first EVICTs least-recently-used blocks held only by the
    /// prefix cache; still short, it drops the adopted prefixes of other
    /// queued-but-idle requests — those fall back to RECOMPUTE (their
    /// blocks stay published and become evictable).  Returns whether the
    /// reservation now fits; `false` leaves the request queued.
    /// Equivalent to `kv.can_grow` when the cache is off.  Worst case is
    /// O(waiting · cache log cache) — only reachable in an OOM-pressure
    /// round, never on the hit/miss fast path.
    pub fn kv_room(&mut self, seq_id: u64, tokens: usize) -> bool {
        if self.kv.can_grow(seq_id, tokens) {
            return true;
        }
        if self.prefix.is_none() {
            self.note_kv_blocked();
            return false;
        }
        let need = self
            .kv
            .blocks_needed(seq_id, tokens)
            .saturating_sub(self.kv.free_blocks());
        self.prefix.as_mut().unwrap().evict_lru(&mut self.kv, need);
        if self.kv.can_grow(seq_id, tokens) {
            return true;
        }
        // Recompute path: un-adopt queued prefixes ONE AT A TIME (never
        // the requester's own), evicting the unpinned blocks after each,
        // and stop as soon as the reservation fits — transient pressure
        // should cost as few queued cache wins as possible.
        for i in 0..self.waiting.len() {
            let (wid, cached, reserved) = {
                let w = &self.waiting[i];
                (w.req.id, w.req.cached_len, w.prefill_start.is_some())
            };
            if wid == seq_id || reserved || cached == 0 {
                continue;
            }
            self.kv.release(wid).expect("drop adopted prefix");
            self.prefix.as_mut().unwrap().note_dropped_adoption(cached);
            self.waiting[i].req.cached_len = 0;
            self.waiting[i].done = 0;
            let need = self
                .kv
                .blocks_needed(seq_id, tokens)
                .saturating_sub(self.kv.free_blocks());
            self.prefix.as_mut().unwrap().evict_lru(&mut self.kv, need);
            if self.kv.can_grow(seq_id, tokens) {
                return true;
            }
        }
        // every mutation above re-checked and returned on success, so
        // reaching here means the reservation still cannot fit
        self.note_kv_blocked();
        false
    }

    /// A KV reservation just failed: flag the turn for the idle-tag
    /// heuristic and record the stall if tracing.
    fn note_kv_blocked(&mut self) {
        self.kv_blocked_turn = true;
        if self.trace_enabled {
            self.trace_buf.push(EngineTraceEvent::KvBlocked { t: self.sim.now() });
        }
    }

    /// Publish a finished prefill's full-block prompt KV into the prefix
    /// index (no-op with the cache off or for unique content).
    fn index_prompt(&mut self, req: &PrefillReq) {
        if self.prefix.is_none() {
            return;
        }
        let Some(meta) = self.prefix_meta.remove(&req.id) else {
            return;
        };
        let chain = meta.chain;
        let full_blocks = (req.input_len / BLOCK_TOKENS).min(chain.len());
        let to_insert = self.kv.get(req.id).and_then(|s| {
            let nb = full_blocks.min(s.blocks.len());
            (nb > 0).then(|| (chain[..nb].to_vec(), s.blocks[..nb].to_vec()))
        });
        if let Some((hashes, blocks)) = to_insert {
            let ix = self.prefix.as_mut().unwrap();
            ix.insert(&mut self.kv, &hashes, &blocks);
        }
    }

    /// Publish the prompt blocks an IN-PROGRESS prefill has already
    /// computed (`done` tokens) into the prefix index, so mid-prompt
    /// arrivals sharing the prefix can hit before the prompt completes.
    /// Chunk-budget engines call this at every chunk boundary; each call
    /// publishes only the DELTA since the last one, and the full publish
    /// at prefill completion is idempotent over these blocks.  No-op
    /// with the cache off or for unique content.
    pub fn publish_progress(&mut self, id: u64, done: usize) {
        if self.prefix.is_none() {
            return;
        }
        let Some(meta) = self.prefix_meta.get_mut(&id) else {
            return;
        };
        let nb = (done / BLOCK_TOKENS).min(meta.chain.len());
        let start = meta.published;
        let to_insert = self.kv.get(id).and_then(|s| {
            let nb = nb.min(s.blocks.len());
            (nb > start).then(|| (meta.chain[start..nb].to_vec(), s.blocks[start..nb].to_vec()))
        });
        if let Some((hashes, blocks)) = to_insert {
            meta.published = start + hashes.len();
            let ix = self.prefix.as_mut().unwrap();
            ix.insert_partial(&mut self.kv, &hashes, &blocks, start);
        }
    }

    /// Complete a request's prefill at the current virtual time:
    /// single-token requests finish outright (record + KV release), the
    /// rest queue for decode-boundary migration.
    pub fn finish_prefill(&mut self, req: PrefillReq, prefill_start: f64) {
        self.index_prompt(&req);
        let now = self.sim.now();
        if req.output_len <= 1 {
            self.records.push(RequestRecord {
                id: req.id,
                arrival: req.arrival,
                input_len: req.input_len,
                output_len: req.output_len,
                first_token_time: now,
                finish_time: now,
                prefill_start,
            });
            self.kv.release(req.id).expect("kv release at prefill finish");
            self.lifecycle.remove(&req.id);
            self.emit_chunk(req.id, 1, true, now);
        } else {
            self.emit_chunk(req.id, 1, false, now);
            self.pending_join.push(ActiveDecode {
                st: DecodeReqState {
                    id: req.id,
                    input_len: req.input_len,
                    ctx_len: req.input_len,
                    tokens_out: 1,
                    output_len: req.output_len,
                    decode_elapsed: 0.0,
                },
                arrival: req.arrival,
                prefill_start,
                first_token_time: now,
                last_token_time: now,
            });
        }
    }

    /// Migrate finished prefills into the decode batch (up to `cap`
    /// members), FIFO.
    pub fn join_pending(&mut self, cap: usize) {
        while self.decode.len() < cap && !self.pending_join.is_empty() {
            self.decode.push(self.pending_join.remove(0));
        }
    }

    /// Credit one generated token to every decode-batch member at the
    /// current virtual time; emit records and release KV for finishers.
    pub fn advance_decode_token(&mut self) {
        let token_time = self.sim.now();
        let mut i = 0;
        while i < self.decode.len() {
            let (id, tokens_out, done) = {
                let d = &mut self.decode[i];
                d.st.tokens_out += 1;
                d.st.ctx_len += 1;
                d.st.decode_elapsed += token_time - d.last_token_time;
                d.last_token_time = token_time;
                (d.st.id, d.st.tokens_out, d.st.finished())
            };
            if done {
                let d = self.decode.remove(i);
                self.records.push(RequestRecord {
                    id: d.st.id,
                    arrival: d.arrival,
                    input_len: d.st.input_len,
                    output_len: d.st.output_len,
                    first_token_time: d.first_token_time,
                    finish_time: token_time,
                    prefill_start: d.prefill_start,
                });
                self.kv.release(d.st.id).expect("kv release at finish");
                self.lifecycle.remove(&id);
            } else {
                i += 1;
            }
            self.emit_chunk(id, tokens_out, done, token_time);
        }
    }

    /// Attach a streaming sink for a request (gateway admission).  Every
    /// produced token is mirrored as a [`StreamChunk`]; a terminal chunk
    /// closes the stream on any exit path.
    pub fn attach_stream(&mut self, id: u64, tx: mpsc::Sender<StreamChunk>) {
        self.sinks.insert(id, tx);
    }

    /// Mirror a token (or terminal event) to the request's sink, if any.
    /// Send failures are ignored: a dropped receiver is exactly a client
    /// that stopped listening, which the cancel path handles separately.
    fn emit_chunk(&mut self, id: u64, tokens_out: usize, done: bool, t: f64) {
        if self.sinks.is_empty() {
            return;
        }
        if done {
            if let Some(tx) = self.sinks.remove(&id) {
                let _ = tx.send(StreamChunk { id, t, tokens_out, done: true });
            }
        } else if let Some(tx) = self.sinks.get(&id) {
            let _ = tx.send(StreamChunk { id, t, tokens_out, done: false });
        }
    }

    /// Terminate a request on a non-completion path: record the outcome,
    /// drop its lifecycle entry, and close its stream.
    fn abort(&mut self, id: u64, outcome: RequestOutcome, t: f64, tokens_out: usize) {
        self.lifecycle.remove(&id);
        self.outcomes.push(OutcomeRecord { id, outcome, t, tokens_out });
        self.emit_chunk(id, tokens_out, true, t);
    }

    /// Enforce due lifecycle events (client disconnects, deadlines) at
    /// the current virtual time.  Requests are removed from whichever
    /// structure holds them and their KV is released — the cancel exit
    /// path through the refcount/CoW invariants.  Two classes defer to a
    /// later turn: waiting-queue entries while the policy holds indices
    /// into the queue (`waiting_locked`) or while their prefill is
    /// mid-flight (KV reserved by in-flight kernels), and requests held
    /// in policy-private batches (invisible here; they resurface in
    /// `pending_join` when the batch completes).
    pub fn apply_lifecycle(&mut self, waiting_locked: bool) {
        if self.lifecycle.is_empty() {
            return;
        }
        let now = self.sim.now();
        let due: Vec<(u64, RequestOutcome)> = self
            .lifecycle
            .iter()
            .filter_map(|(&id, m)| {
                if matches!(m.cancel_at, Some(t) if t <= now) {
                    Some((id, RequestOutcome::Cancelled))
                } else if deadline_should_drop(now, m.deadline, 0.0) {
                    Some((id, RequestOutcome::Expired))
                } else {
                    None
                }
            })
            .collect();
        for (id, outcome) in due {
            if let Some(i) = self.waiting.iter().position(|w| w.req.id == id) {
                if waiting_locked || self.waiting[i].prefill_start.is_some() {
                    continue; // deferred: caught on a later turn
                }
                self.waiting.remove(i);
                if self.kv.contains(id) {
                    // adopted prefix blocks — unpin them
                    self.kv.release(id).expect("kv release at queued cancel");
                }
                self.prefix_meta.remove(&id);
                self.abort(id, outcome, now, 0);
            } else if let Some(i) = self.pending_join.iter().position(|d| d.st.id == id) {
                let d = self.pending_join.remove(i);
                self.kv.release(id).expect("kv release at pending cancel");
                self.abort(id, outcome, now, d.st.tokens_out);
            } else if let Some(i) = self.decode.iter().position(|d| d.st.id == id) {
                let d = self.decode.remove(i);
                self.kv.release(id).expect("kv release at decode cancel");
                self.abort(id, outcome, now, d.st.tokens_out);
            }
            // else: policy-private (active prefill batch) — deferred
        }
    }

    /// Kill this engine at `t`: the replica-crash path.  Admitted
    /// requests whose prefill never started are returned for re-queueing
    /// elsewhere (arrival re-stamped to `t`), as is the
    /// injected-but-unadmitted tail; everything with prefill progress on
    /// this GPU — mid-prefill, pending-join, decoding, or held in a
    /// policy-private batch — is unrecoverable and counted `Lost`.  All
    /// KV is torn down (the pool dies with the GPU) and every remaining
    /// stream is closed.  Afterwards the engine is drained and finished.
    pub fn crash(&mut self, t: f64) -> Vec<Request> {
        // Re-queue: waiting entries with no prefill progress...
        let requeue_ids: Vec<u64> = self
            .waiting
            .iter()
            .filter(|w| w.prefill_start.is_none())
            .map(|w| w.req.id)
            .collect();
        let mut requeued: Vec<Request> = Vec::new();
        for &id in &requeue_ids {
            let mut r = self
                .trace
                .iter()
                .find(|r| r.id == id)
                .expect("waiting request must be in trace")
                .clone();
            // admission moved the hash chain into prefix_meta; restore
            // it so the new home can re-match the prefix cache
            if r.block_hashes.is_empty() {
                if let Some(meta) = self.prefix_meta.get(&id) {
                    r.block_hashes = meta.chain.clone();
                }
            }
            r.arrival = t;
            requeued.push(r);
        }
        // ...plus the injected-but-unadmitted tail.
        let mut gone_ids = requeue_ids.clone();
        for r in &self.trace[self.next_arrival.min(self.trace.len())..] {
            gone_ids.push(r.id);
            let mut r = r.clone();
            r.arrival = t;
            requeued.push(r);
        }
        // Everything else admitted but unaccounted is lost with the GPU.
        let mut lost: Vec<(u64, usize)> = Vec::new();
        for r in &self.trace[..self.next_arrival.min(self.trace.len())] {
            let id = r.id;
            if requeue_ids.contains(&id)
                || self.records.iter().any(|rec| rec.id == id)
                || self.outcomes.iter().any(|o| o.id == id)
            {
                continue;
            }
            let tokens = self
                .pending_join
                .iter()
                .chain(self.decode.iter())
                .find(|d| d.st.id == id)
                .map(|d| d.st.tokens_out)
                .unwrap_or(0);
            lost.push((id, tokens));
        }
        for (id, tokens) in lost {
            self.abort(id, RequestOutcome::Lost, t, tokens);
        }
        // Tear down: release every live sequence (including any a policy
        // reserved privately), drop the cache, close surviving streams.
        for id in self.kv.seq_ids() {
            self.kv.release(id).expect("kv release at crash");
        }
        if let Some(ix) = self.prefix.as_mut() {
            ix.clear(&mut self.kv);
        }
        debug_assert_eq!(self.kv.used_blocks(), 0, "crash must return the pool whole");
        self.waiting.clear();
        self.decode.clear();
        self.pending_join.clear();
        self.prefix_meta.clear();
        self.lifecycle.clear();
        self.sinks.clear();
        self.trace.retain(|r| !gone_ids.contains(&r.id));
        debug_assert_eq!(
            self.trace.len(),
            self.records.len() + self.outcomes.len(),
            "crash left the trace unpartitioned"
        );
        self.next_arrival = self.trace.len();
        requeued
    }

    /// Scheduler-visible snapshot (S_k of §3.3.2).  The policy passes its
    /// active prefill batch, which the core does not track.
    pub fn snapshot(&self, prefill: &Option<PrefillBatch>) -> SystemState {
        SystemState {
            now: self.sim.now(),
            prefill: prefill.clone(),
            decode: self.decode.iter().map(|d| d.st.clone()).collect(),
            waiting: self.waiting.iter().map(|w| w.req.clone()).collect(),
            partition: self.rm.partition(),
            total_layers: self.cfg.model.n_layers,
        }
    }

    /// Record a timeline sample if enabled.
    pub fn sample_timeline(&mut self, prefill_tokens: usize) {
        if !self.record_timeline {
            return;
        }
        let w = self.sim.take_util_window();
        let gpu = self.sim.gpu().clone();
        self.timeline.push(TimelineSample {
            t: self.sim.now(),
            prefill_sms: self.rm.partition().prefill_sms,
            decode_sms: self.rm.partition().decode_sms,
            prefill_tokens,
            decode_batch: self.decode.len(),
            waiting: self.waiting.len(),
            compute_util: w.compute_util(&gpu),
            bandwidth_util: w.bandwidth_util(&gpu),
            calib_samples: self.stats.calib.samples,
            calib_residual: self.stats.calib.mean_abs_residual(),
        });
    }

    /// Requests injected but not yet admitted into the waiting queue.
    /// With bounded `run_until` advances the clock can trail (or
    /// overshoot) the dispatch instant, leaving freshly-routed requests
    /// in this gap — routing signals must count them or a state-aware
    /// dispatcher goes blind to its own recent decisions.
    fn pending_injected(&self) -> &[Request] {
        &self.trace[self.next_arrival.min(self.trace.len())..]
    }

    /// KV tokens this replica is committed to: reserved pool tokens plus
    /// the reservations queued and injected-but-unadmitted requests will
    /// make (cluster routing signal).
    pub fn outstanding_kv_tokens(&self) -> usize {
        // adopted prefix tokens already count in `kv.cached_tokens()`
        let queued: usize = self
            .waiting
            .iter()
            .filter(|w| w.prefill_start.is_none())
            .map(|w| w.req.input_len + w.req.output_len - w.req.cached_len)
            .sum();
        let injected: usize = self
            .pending_injected()
            .iter()
            .map(|r| r.input_len + r.output_len)
            .sum();
        self.kv.cached_tokens() + queued + injected
    }

    /// Prompt tokens still to prefill across the waiting queue and the
    /// injected-but-unadmitted tail (cluster routing signal;
    /// policy-private batches are reported separately).
    pub fn queued_prefill_tokens(&self) -> usize {
        let waiting: usize = self.waiting.iter().map(|w| w.remaining()).sum();
        let injected: usize = self.pending_injected().iter().map(|r| r.input_len).sum();
        waiting + injected
    }

    /// Drive the loop until every record is emitted.
    pub fn run<P: ServingPolicy + ?Sized>(&mut self, policy: &mut P) {
        self.pump(policy, None);
    }

    /// Drive the loop until virtual time reaches `until` (or the trace
    /// completes).  The clock may overshoot slightly: a kernel completion
    /// is never split.  Used by the cluster layer to co-advance replicas.
    pub fn run_until<P: ServingPolicy + ?Sized>(&mut self, policy: &mut P, until: f64) {
        self.pump(policy, Some(until));
    }

    /// Absolute idle jump with ledger attribution: the span is charged
    /// under `tag` (kv-blocked / repartition / free-residual).  The tag
    /// is bracketed — set, jump, reset — so no stale tag can leak into
    /// later jumps (in particular the cluster layer's drained-replica
    /// fast-forward, which bypasses the pump entirely).
    fn idle_jump(&mut self, target: f64, tag: IdleTag) {
        self.sim.set_idle_tag(tag);
        self.sim.advance_idle_to(target + 1e-9);
        self.sim.set_idle_tag(IdleTag::Free);
    }

    /// Classify the idle span the pump is about to jump over.  Heuristic,
    /// priority-ordered: no work anywhere → plain idle (the finalize
    /// residual); a turn that saw a failed KV reservation → `KvBlocked`;
    /// a plan that repartitioned the SM split but launched nothing →
    /// `Repartition` (the transition gap); otherwise plain idle.
    fn stall_tag(&self, policy_private: bool) -> IdleTag {
        let has_work =
            !self.waiting.is_empty() || !self.decode.is_empty() || !self.pending_join.is_empty() || policy_private;
        if !has_work {
            IdleTag::Free
        } else if self.kv_blocked_turn {
            IdleTag::KvBlocked
        } else if self.rm.reconfig_count() > self.reconfigs_seen {
            IdleTag::Repartition
        } else {
            IdleTag::Free
        }
    }

    fn pump<P: ServingPolicy + ?Sized>(&mut self, policy: &mut P, until: Option<f64>) {
        // Guard against a policy that spins without making progress.
        let mut idle_spins = 0u32;
        while !self.finished() {
            let now = self.sim.now();
            if let Some(t) = until {
                if now >= t {
                    return;
                }
            }
            if now > self.max_virtual_time {
                panic!(
                    "virtual time cap exceeded: {} records of {} done at t={now}",
                    self.records.len(),
                    self.trace.len()
                );
            }

            self.admit_arrivals();
            self.apply_lifecycle(policy.waiting_locked());
            if self.finished() {
                return;
            }
            self.kv_blocked_turn = false;
            self.reconfigs_seen = self.rm.reconfig_count();
            policy.plan(self);
            if self.trace_enabled && self.rm.reconfig_count() > self.reconfigs_seen {
                let p = self.rm.partition();
                self.trace_buf.push(EngineTraceEvent::Repartition {
                    t: self.sim.now(),
                    prefill_sms: p.prefill_sms,
                    decode_sms: p.decode_sms,
                });
            }

            if self.sim.idle() {
                if self.next_arrival < self.trace.len() {
                    // Jump to the next arrival (capped by `until`).
                    let mut target = self.trace[self.next_arrival].arrival;
                    if let Some(t) = until {
                        target = target.min(t);
                    }
                    let tag = self.stall_tag(policy.has_private_work());
                    self.idle_jump(target, tag);
                    continue;
                }
                // No pending arrivals.
                if self.waiting.is_empty()
                    && self.decode.is_empty()
                    && self.pending_join.is_empty()
                    && !policy.has_private_work()
                {
                    if let Some(t) = until {
                        // Genuinely drained before the bound: idle to it.
                        self.sim.advance_idle_to(t + 1e-9);
                        return;
                    }
                    unreachable!(
                        "no work left but {} requests unaccounted",
                        self.trace.len() - self.records.len() - self.outcomes.len()
                    );
                }
                // Work exists but nothing launched: let the policy
                // recover (unpause, wait out a memory stall) — also
                // under a bound, or a paused replica would freeze for
                // the whole cluster-dispatch phase.
                if policy.on_stall(self) {
                    idle_spins = 0;
                    continue;
                }
                if let Some(t) = until {
                    // Unrecoverable before the bound: idle up to it.
                    let tag = self.stall_tag(policy.has_private_work());
                    self.idle_jump(t, tag);
                    return;
                }
                idle_spins += 1;
                assert!(
                    idle_spins < 1_000_000,
                    "engine wedged: {} of {} records at t={now}, nothing in flight",
                    self.records.len(),
                    self.trace.len()
                );
                continue;
            }
            idle_spins = 0;

            self.sim.step();
            for c in self.sim.take_completions() {
                let lane = if self.rm.is_prefill_stream(c.stream) {
                    Lane::Prefill
                } else {
                    Lane::Decode
                };
                let i = lane as usize;
                debug_assert!(self.inflight[i] > 0, "completion on an idle lane");
                self.inflight[i] -= 1;
                if self.inflight[i] == 0 {
                    policy.on_drain(lane, self);
                }
            }
        }
    }

    /// Tear down into the run-level output.
    pub fn into_output(self) -> EngineOutput {
        let util = self.sim.total_util();
        let prefix = self.prefix.as_ref().map(|ix| *ix.stats()).unwrap_or_default();
        let mut ledger = self.sim.ledger();
        ledger.finalize(self.sim.gpu().num_sms as f64 * self.sim.now());
        EngineOutput {
            prefix,
            calibration: self.stats.calib,
            scale_events: Vec::new(),
            rate_memo: self.sim.rate_memo_counters(),
            predict_memo: self.stats.predict_memo,
            ledger,
            trace_events: self.trace_buf,
            records: self.records,
            outcomes: self.outcomes,
            timeline: self.timeline,
            reconfigs: self.rm.reconfig_count(),
            decode_pauses: self.stats.decode_pauses,
            total_flops: util.flops,
            total_bytes: util.bytes,
            virtual_duration: self.sim.now(),
            peak_kv_blocks: self.kv.peak_used_blocks(),
            final_kv_blocks: self.kv.used_blocks(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::phases::{decode_all_layers, PhaseShape};

    fn core_with(trace: Vec<Request>) -> EngineCore {
        let cfg = ServingConfig::default();
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        EngineCore::new(cfg, gt, trace, &CoreOptions::default())
    }

    /// A minimal policy: whole-GPU decode-only engine that "prefills"
    /// instantly at admission.  Exercises the harness lifecycle without
    /// any scheduling logic — the ~20-line policy floor.
    struct InstantPrefill;

    impl ServingPolicy for InstantPrefill {
        fn label(&self) -> String {
            "instant-prefill".into()
        }

        fn plan(&mut self, core: &mut EngineCore) {
            if !core.all_idle() {
                return;
            }
            while let Some(w) = core.waiting.pop() {
                core.kv
                    .grow(w.req.id, w.req.input_len + w.req.output_len)
                    .unwrap();
                core.finish_prefill(w.req, core.now());
            }
            core.join_pending(usize::MAX);
            if !core.decode.is_empty() {
                let bs = core.decode.len();
                let stream = core.rm.decode_stream_for(core.cfg.gpu.num_sms);
                let kernels =
                    decode_all_layers(&core.cfg.model, PhaseShape { tokens: bs, context: 64 });
                core.submit(Lane::Decode, stream, kernels);
            }
        }

        fn on_drain(&mut self, lane: Lane, core: &mut EngineCore) {
            if lane == Lane::Decode {
                core.advance_decode_token();
            }
        }
    }

    #[test]
    fn minimal_policy_serves_trace() {
        let trace: Vec<Request> = (0..5)
            .map(|i| Request {
                id: i,
                arrival: i as f64 * 0.01,
                input_len: 64,
                output_len: 4,
                ..Default::default()
            })
            .collect();
        let mut core = core_with(trace);
        core.run(&mut InstantPrefill);
        let out = core.into_output();
        assert_eq!(out.records.len(), 5);
        for r in &out.records {
            assert!(r.finish_time >= r.first_token_time);
            assert!(r.first_token_time >= r.arrival);
        }
        assert!(out.peak_kv_blocks > 0);
    }

    #[test]
    fn run_until_bounds_the_clock() {
        let trace: Vec<Request> = (0..8)
            .map(|i| Request {
                id: i,
                arrival: i as f64 * 0.5,
                input_len: 64,
                output_len: 200,
                ..Default::default()
            })
            .collect();
        let mut core = core_with(trace);
        let mut p = InstantPrefill;
        core.run_until(&mut p, 1.0);
        assert!(core.now() >= 1.0 - 1e-9);
        // far from done: later arrivals not yet served
        assert!(!core.finished());
        core.run(&mut p);
        assert!(core.finished());
        assert_eq!(core.records.len(), 8);
    }

    #[test]
    fn push_request_extends_a_finished_run() {
        let mut core = core_with(vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 32,
            output_len: 2,
            ..Default::default()
        }]);
        let mut p = InstantPrefill;
        core.run(&mut p);
        assert!(core.finished());
        core.push_request(Request {
            id: 1,
            arrival: core.now() + 1.0,
            input_len: 32,
            output_len: 2,
            ..Default::default()
        });
        assert!(!core.finished());
        core.run(&mut p);
        assert_eq!(core.records.len(), 2);
    }

    #[test]
    fn routing_signals_count_unadmitted_injections() {
        let mut core = core_with(vec![]);
        assert_eq!(core.outstanding_kv_tokens(), 0);
        assert_eq!(core.queued_prefill_tokens(), 0);
        core.push_request(Request { id: 0, arrival: 1.0, input_len: 100, output_len: 10, ..Default::default() });
        core.push_request(Request { id: 1, arrival: 2.0, input_len: 50, output_len: 5, ..Default::default() });
        // clock still at 0, nothing admitted — but a state-aware
        // dispatcher must see its own recent routing decisions.
        assert_eq!(core.outstanding_kv_tokens(), 165);
        assert_eq!(core.queued_prefill_tokens(), 150);
    }

    use crate::testing::content_chain as chain;

    #[test]
    fn admission_adopts_cached_prefix_and_charges_suffix() {
        let cfg = ServingConfig { prefix_cache: true, ..ServingConfig::default() };
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        // two requests with identical 130-token prompts (8 full blocks)
        let hashes = chain(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let trace: Vec<Request> = (0..2)
            .map(|i| Request {
                id: i,
                arrival: i as f64,
                input_len: 130,
                output_len: 1,
                block_hashes: hashes.clone(),
                session_id: Some(77),
                ..Default::default()
            })
            .collect();
        let mut core = EngineCore::new(cfg, gt, trace, &CoreOptions::default());
        core.admit_arrivals();
        assert_eq!(core.waiting.len(), 1, "only the t=0 arrival is due");
        let w0 = core.waiting.remove(0);
        assert_eq!(w0.req.cached_len, 0, "cold cache: nothing to adopt");
        // run its prefill by hand and finish — publishes 8 blocks
        core.kv.grow(w0.req.id, w0.req.input_len + w0.req.output_len).unwrap();
        core.finish_prefill(w0.req, 0.0);
        assert_eq!(core.prefix.as_ref().unwrap().len(), 8);
        assert_eq!(core.kv.used_blocks(), 8, "prompt blocks outlive the request");
        // the identical second prompt adopts every full block but the
        // last token's
        core.sim.run_for(1.5);
        core.admit_arrivals();
        let w1 = &core.waiting[0];
        assert_eq!(w1.req.cached_len, 128);
        assert_eq!(w1.done, 128);
        assert_eq!(w1.remaining(), 2);
        assert!(core.kv.contains(1), "adopted seq must exist");
        assert_eq!(core.kv.get(1).unwrap().len, 128);
        let s = core.prefix.as_ref().unwrap().stats();
        assert_eq!(s.lookups, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.cached_tokens, 128);
    }

    #[test]
    fn kv_room_evicts_cache_only_blocks_under_pressure() {
        let cfg = ServingConfig {
            prefix_cache: true,
            kv_capacity_tokens: 4 * BLOCK_TOKENS,
            ..ServingConfig::default()
        };
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let mut core = EngineCore::new(cfg, gt, vec![], &CoreOptions::default());
        // fill half the pool with cache-only blocks
        core.kv.grow(100, 2 * BLOCK_TOKENS).unwrap();
        let blocks = core.kv.get(100).unwrap().blocks.clone();
        let hashes = chain(&[41, 42]);
        core.prefix.as_mut().unwrap().insert(&mut core.kv, &hashes, &blocks);
        core.kv.release(100).unwrap();
        assert_eq!(core.kv.free_blocks(), 2);
        // a 3-block reservation requires evicting a cached block
        assert!(core.kv_room(7, 3 * BLOCK_TOKENS), "eviction must make room");
        assert!(core.kv.free_blocks() >= 3);
        assert_eq!(core.prefix.as_ref().unwrap().stats().evictions, 1);
        // impossible reservations still fail cleanly
        assert!(!core.kv_room(7, 100 * BLOCK_TOKENS));
    }

    #[test]
    fn kv_room_recompute_drops_idle_adoptions_and_accounts_them() {
        let cfg = ServingConfig {
            prefix_cache: true,
            kv_capacity_tokens: 4 * BLOCK_TOKENS,
            ..ServingConfig::default()
        };
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let mut core = EngineCore::new(cfg, gt, vec![], &CoreOptions::default());
        // seed the index with a 2-block chain, then release the seq
        core.kv.grow(100, 2 * BLOCK_TOKENS).unwrap();
        let blocks = core.kv.get(100).unwrap().blocks.clone();
        let hashes = chain(&[61, 62]);
        core.prefix.as_mut().unwrap().insert(&mut core.kv, &hashes, &blocks);
        core.kv.release(100).unwrap();
        // admit a request that adopts the cached prefix (pins the blocks)
        core.push_request(Request {
            id: 0,
            arrival: 0.0,
            input_len: 2 * BLOCK_TOKENS + 8,
            output_len: 4,
            block_hashes: hashes,
            session_id: None,
            ..Default::default()
        });
        core.admit_arrivals();
        assert_eq!(core.waiting[0].req.cached_len, 2 * BLOCK_TOKENS);
        // a 4-block reservation cannot fit while the adoption pins the
        // cached blocks (refcount 2 ⇒ unevictable): the recompute path
        // must drop the idle adoption, unpin, and evict
        assert!(core.kv_room(9, 4 * BLOCK_TOKENS));
        assert_eq!(core.waiting[0].req.cached_len, 0, "adoption revoked");
        assert_eq!(core.waiting[0].done, 0, "request falls back to a full prefill");
        assert!(!core.kv.contains(0));
        let s = *core.prefix.as_ref().unwrap().stats();
        assert_eq!(s.dropped_adoptions, 1);
        assert_eq!(s.dropped_tokens, 2 * BLOCK_TOKENS as u64);
        assert_eq!(s.tokens_saved(), 0, "revoked tokens are not savings");
    }

    #[test]
    fn prefix_cache_off_leaves_admission_untouched() {
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let trace = vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 130,
            output_len: 1,
            block_hashes: chain(&[1, 2, 3, 4, 5, 6, 7, 8]),
            session_id: Some(1),
            ..Default::default()
        }];
        let mut core = core_with(trace);
        core.admit_arrivals();
        assert!(core.prefix.is_none());
        assert_eq!(core.waiting[0].req.cached_len, 0);
        assert_eq!(core.waiting[0].done, 0);
    }

    /// A policy that never launches anything — for driving lifecycle
    /// enforcement on queued work via bounded runs.
    struct NeverLaunch;

    impl ServingPolicy for NeverLaunch {
        fn label(&self) -> String {
            "never-launch".into()
        }

        fn plan(&mut self, _core: &mut EngineCore) {}

        fn on_drain(&mut self, _lane: Lane, _core: &mut EngineCore) {}
    }

    #[test]
    fn queued_request_cancels_without_ever_running() {
        let mut core = core_with(vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 64,
            output_len: 8,
            cancel_at: Some(0.5),
            ..Default::default()
        }]);
        let mut p = NeverLaunch;
        core.run_until(&mut p, 1.0);
        assert!(core.now() >= 1.0 - 1e-9);
        core.run_until(&mut p, 2.0);
        assert!(core.finished());
        assert!(core.waiting.is_empty());
        assert_eq!(core.records.len(), 0);
        assert_eq!(core.outcomes.len(), 1);
        let o = &core.outcomes[0];
        assert_eq!(o.outcome, RequestOutcome::Cancelled);
        assert_eq!(o.tokens_out, 0);
        assert!(o.t >= 0.5);
        assert_eq!(core.kv.used_blocks(), 0);
    }

    #[test]
    fn mid_decode_cancel_releases_kv_and_counts() {
        let mut core = core_with(vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 64,
            output_len: 10_000,
            cancel_at: Some(0.05),
            ..Default::default()
        }]);
        core.run(&mut InstantPrefill);
        assert_eq!(core.records.len(), 0, "cancelled request must not complete");
        assert_eq!(core.outcomes.len(), 1);
        let o = &core.outcomes[0];
        assert_eq!(o.outcome, RequestOutcome::Cancelled);
        assert!(o.tokens_out >= 1, "was decoding when the client left");
        assert!(o.t >= 0.05);
        let out = core.into_output();
        assert_eq!(out.final_kv_blocks, 0, "cancel must return KV to the pool");
    }

    #[test]
    fn deadline_expires_mid_decode() {
        let mut core = core_with(vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 64,
            output_len: 10_000,
            deadline: Some(0.05),
            ..Default::default()
        }]);
        core.run(&mut InstantPrefill);
        assert_eq!(core.outcomes.len(), 1);
        assert_eq!(core.outcomes[0].outcome, RequestOutcome::Expired);
        assert!(
            core.outcomes[0].tokens_out < 10_000,
            "expired request must not run to completion"
        );
        assert_eq!(core.kv.used_blocks(), 0);
    }

    #[test]
    fn cancel_beats_deadline_when_both_due() {
        let mut core = core_with(vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 64,
            output_len: 10_000,
            cancel_at: Some(0.05),
            deadline: Some(0.05),
            ..Default::default()
        }]);
        core.run(&mut InstantPrefill);
        assert_eq!(core.outcomes.len(), 1);
        assert_eq!(core.outcomes[0].outcome, RequestOutcome::Cancelled);
    }

    #[test]
    fn streams_mirror_every_token_and_close() {
        let (tx, rx) = mpsc::channel();
        let mut core = core_with(vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 64,
            output_len: 4,
            ..Default::default()
        }]);
        core.attach_stream(0, tx);
        core.run(&mut InstantPrefill);
        let chunks: Vec<StreamChunk> = rx.try_iter().collect();
        assert_eq!(chunks.len(), 4, "one chunk per output token");
        assert!(chunks.windows(2).all(|w| w[0].t <= w[1].t));
        assert!(chunks.last().unwrap().done);
        assert_eq!(chunks.last().unwrap().tokens_out, 4);
        assert_eq!(chunks[0].t, core.records[0].first_token_time);
        assert_eq!(chunks.last().unwrap().t, core.records[0].finish_time);
    }

    #[test]
    fn crash_requeues_cold_work_and_loses_inflight() {
        let mut core = core_with(vec![
            Request { id: 0, arrival: 0.0, input_len: 64, output_len: 10_000, ..Default::default() },
            Request { id: 1, arrival: 500.0, input_len: 32, output_len: 4, ..Default::default() },
        ]);
        let mut p = InstantPrefill;
        core.run_until(&mut p, 0.05);
        assert!(!core.decode.is_empty(), "id 0 must be decoding at crash time");
        let t = core.now();
        let requeued = core.crash(t);
        // id 1 never reached this GPU: re-queued with arrival re-stamped
        assert_eq!(requeued.len(), 1);
        assert_eq!(requeued[0].id, 1);
        assert_eq!(requeued[0].arrival, t);
        // id 0 had decode progress here: lost with the GPU
        assert_eq!(core.outcomes.len(), 1);
        assert_eq!(core.outcomes[0].outcome, RequestOutcome::Lost);
        assert!(core.outcomes[0].tokens_out >= 1);
        assert_eq!(core.kv.used_blocks(), 0, "crash returns the pool whole");
        assert!(core.finished());
        assert!(core.drained());
    }

    #[test]
    fn lifecycle_free_trace_is_untouched_by_enforcement() {
        let trace: Vec<Request> = (0..5)
            .map(|i| Request {
                id: i,
                arrival: i as f64 * 0.01,
                input_len: 64,
                output_len: 4,
                ..Default::default()
            })
            .collect();
        let mut core = core_with(trace);
        core.run(&mut InstantPrefill);
        let out = core.into_output();
        assert_eq!(out.records.len(), 5);
        assert!(out.outcomes.is_empty());
        assert_eq!(out.final_kv_blocks, 0);
    }

    #[test]
    fn single_token_requests_skip_decode() {
        let mut core = core_with(vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 128,
            output_len: 1,
            ..Default::default()
        }]);
        core.run(&mut InstantPrefill);
        let out = core.into_output();
        assert_eq!(out.records[0].first_token_time, out.records[0].finish_time);
    }

    #[test]
    fn output_ledger_conserves_gpu_time() {
        let trace: Vec<Request> = (0..5)
            .map(|i| Request {
                id: i,
                arrival: i as f64 * 0.01,
                input_len: 64,
                output_len: 4,
                ..Default::default()
            })
            .collect();
        let mut core = core_with(trace);
        core.run(&mut InstantPrefill);
        let sms = core.sim.gpu().num_sms as f64;
        let out = core.into_output();
        assert_eq!(out.ledger.total, sms * out.virtual_duration);
        assert!(out.ledger.conserved(1e-9), "{:?}", out.ledger);
        assert!(out.ledger.decode > 0.0, "decode-only policy: {:?}", out.ledger);
        assert!(out.trace_events.is_empty(), "tracing defaults off");
    }

    #[test]
    fn trace_on_records_launches_deterministically() {
        use crate::obs::TraceSpec;
        let mk = || {
            let cfg = ServingConfig { trace: TraceSpec::on(), ..ServingConfig::default() };
            let gt = GroundTruth::noiseless(GpuSpec::a100());
            let trace: Vec<Request> = (0..3)
                .map(|i| Request {
                    id: i,
                    arrival: i as f64 * 0.01,
                    input_len: 64,
                    output_len: 4,
                    ..Default::default()
                })
                .collect();
            let mut core = EngineCore::new(cfg, gt, trace, &CoreOptions::default());
            core.run(&mut InstantPrefill);
            core.into_output()
        };
        let a = mk();
        let b = mk();
        assert!(
            a.trace_events.iter().any(|e| matches!(e, EngineTraceEvent::Launch { .. })),
            "launches must be recorded with tracing on"
        );
        assert_eq!(a.trace_events, b.trace_events, "trace must be deterministic");
        assert_eq!(a.ledger.to_bits(), b.ledger.to_bits());
    }

    /// Sees the queued request, probes for KV it can never get, and
    /// launches nothing — a memory-wedged engine.
    struct BlockedByKv;

    impl ServingPolicy for BlockedByKv {
        fn label(&self) -> String {
            "blocked-by-kv".into()
        }

        fn plan(&mut self, core: &mut EngineCore) {
            if let Some(w) = core.waiting.first() {
                let (id, need) = (w.req.id, w.req.input_len + w.req.output_len);
                assert!(!core.kv_room(id, need), "pool is sized to never fit");
            }
        }

        fn on_drain(&mut self, _lane: Lane, _core: &mut EngineCore) {}
    }

    #[test]
    fn kv_pressure_stall_charges_kv_blocked() {
        let cfg = ServingConfig { kv_capacity_tokens: 64, ..ServingConfig::default() };
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let trace = vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 4096,
            output_len: 8,
            ..Default::default()
        }];
        let mut core = EngineCore::new(cfg, gt, trace, &CoreOptions::default());
        core.run_until(&mut BlockedByKv, 2.0);
        let l = core.sim.ledger();
        assert!(l.kv_blocked > 0.0, "blocked idle must be attributed: {l:?}");
        assert_eq!(l.repartition, 0.0);
    }

    /// Flips the SM partition every turn without ever launching — pure
    /// repartition-transition idle.
    struct FlipFlop(bool);

    impl ServingPolicy for FlipFlop {
        fn label(&self) -> String {
            "flip-flop".into()
        }

        fn plan(&mut self, core: &mut EngineCore) {
            let sms = if self.0 { 60 } else { 54 };
            self.0 = !self.0;
            let p = crate::resource::Partition::split(&core.cfg.gpu, sms);
            core.rm.reconfigure(p);
        }

        fn on_drain(&mut self, _lane: Lane, _core: &mut EngineCore) {}
    }

    #[test]
    fn repartition_only_stall_charges_repartition() {
        let trace = vec![Request {
            id: 0,
            arrival: 0.0,
            input_len: 64,
            output_len: 8,
            ..Default::default()
        }];
        let mut core = core_with(trace);
        core.run_until(&mut FlipFlop(true), 1.0);
        let l = core.sim.ledger();
        assert!(l.repartition > 0.0, "transition idle must be attributed: {l:?}");
        assert_eq!(l.kv_blocked, 0.0);
    }
}
