//! Fig. 11: end-to-end latency, throughput and SLO attainment of every
//! system across the three workloads at multiple request rates.
//!
//! Paper anchors: Bullet achieves the highest throughput (1.09× avg,
//! up to 1.20× vs SGLang-1024) and SLO compliance (1.49×), with mean
//! TTFT ~13.5× better and TPOT ~0.94× (slightly worse) than SGLang-1024;
//! SGLang-2048 improves TTFT over SGLang-1024 at a TPOT cost.

use bullet::baselines::{run_system, System};
use bullet::config::{ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::summarize;
use bullet::util::tbl::{f, ms, Table};
use bullet::workload::{generate_n_requests, Dataset};

fn main() {
    let n = std::env::var("BULLET_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120usize);
    let seed = 42;

    let mut bullet_gains: Vec<(f64, f64, f64)> = Vec::new(); // (thpt, ttft, slo) vs sglang-1024

    for ds in Dataset::all() {
        let (slo, rates): (SloSpec, &[f64]) = match ds.name {
            "azure-code" => (SloSpec::azure_code(), &[3.0, 5.0, 8.0]),
            "arxiv-summary" => (SloSpec::arxiv_summary(), &[1.0, 1.5, 2.0]),
            _ => (SloSpec::sharegpt(), &[10.0, 15.0, 20.0]),
        };
        let cfg = ServingConfig { slo, ..ServingConfig::default() };
        let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));

        for &rate in rates {
            let trace = generate_n_requests(&ds, rate, n, seed);
            let mut t = Table::new(&format!("Fig. 11 — {} @ {} req/s", ds.name, rate)).header(&[
                "system",
                "mean TTFT ms",
                "P90 TTFT ms",
                "mean TPOT ms",
                "tok/s",
                "SLO %",
            ]);
            let mut rows = Vec::new();
            for sys in System::evaluation_set() {
                let recs =
                    run_system(sys, &cfg, server.perf(), server.ground_truth(), &trace, seed);
                let s = summarize(&recs, &cfg.slo, None);
                rows.push((sys, s));
            }
            for (sys, s) in &rows {
                t.row(&[
                    sys.label(),
                    ms(s.mean_ttft),
                    ms(s.p90_ttft),
                    ms(s.mean_tpot),
                    f(s.throughput_tok_s, 0),
                    f(s.slo_attainment * 100.0, 1),
                ]);
            }
            t.print();
            let sg = rows.iter().find(|(s, _)| *s == System::Sglang1024).unwrap();
            let bu = rows.iter().find(|(s, _)| *s == System::Bullet).unwrap();
            let g = (
                bu.1.throughput_tok_s / sg.1.throughput_tok_s,
                sg.1.mean_ttft / bu.1.mean_ttft,
                if sg.1.slo_attainment > 0.0 {
                    bu.1.slo_attainment / sg.1.slo_attainment
                } else {
                    f64::NAN
                },
            );
            println!(
                "Bullet vs SGLang-1024: throughput {:.2}x | TTFT {:.1}x better | SLO {:.2}x\n",
                g.0, g.1, g.2
            );
            bullet_gains.push(g);
        }
    }

    let mean = |sel: fn(&(f64, f64, f64)) -> f64| {
        let v: Vec<f64> = bullet_gains.iter().map(sel).filter(|x| x.is_finite()).collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    println!(
        "=== aggregate (Bullet vs SGLang-1024 across all workloads/rates) ===\n\
         mean throughput gain {:.2}x (paper: 1.09x avg, up to 1.20x)\n\
         mean TTFT improvement {:.1}x (paper: 13.5x)\n\
         mean SLO-compliance gain {:.2}x (paper: 1.49x)",
        mean(|g| g.0),
        mean(|g| g.1),
        mean(|g| g.2),
    );
}
