//! Fig. 14: component ablation — Naive (concurrency only), w/Partition
//! (resource provision only), w/Scheduler (reordering + delayed decode
//! only), and full Bullet, across all three workloads.
//!
//! Paper anchors: Naive shows the latency imbalance (good TTFT, bad
//! TPOT from unpartitioned contention); w/Partition fixes TPOT but
//! degrades TTFT without reordering; w/Scheduler is balanced but leaves
//! contention; only the full design balances both everywhere.

use bullet::baselines::{run_system, System};
use bullet::config::{ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::summarize;
use bullet::util::tbl::{f, ms, Table};
use bullet::workload::{generate_n_requests, Dataset};

fn main() {
    let n = 100;
    let seed = 14;
    for ds in Dataset::all() {
        let (slo, rate) = match ds.name {
            "azure-code" => (SloSpec::azure_code(), 5.0),
            "arxiv-summary" => (SloSpec::arxiv_summary(), 1.5),
            _ => (SloSpec::sharegpt(), 12.0),
        };
        let cfg = ServingConfig { slo, ..ServingConfig::default() };
        let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
        let trace = generate_n_requests(&ds, rate, n, seed);

        let mut t = Table::new(&format!("Fig. 14 — ablation, {} @ {} req/s", ds.name, rate))
            .header(&["variant", "mean TTFT ms", "P90 TTFT ms", "mean TPOT ms", "SLO %"]);
        for sys in System::ablation_set() {
            let recs = run_system(sys, &cfg, server.perf(), server.ground_truth(), &trace, seed);
            let s = summarize(&recs, &cfg.slo, None);
            t.row(&[
                sys.label(),
                ms(s.mean_ttft),
                ms(s.p90_ttft),
                ms(s.mean_tpot),
                f(s.slo_attainment * 100.0, 1),
            ]);
        }
        t.print();
        println!();
    }
    println!(
        "Shape check: each partial variant optimizes one metric at the other's expense on at\n\
         least one workload; the full design (partitioning + SLO scheduling) is the only row\n\
         that stays balanced across all three workloads."
    );
}
