//! Fig. 13: sensitivity to FIXED prefill-SM allocations (decode gets the
//! whole GPU) vs Bullet's dynamic tuning.
//!
//! Paper anchors (Azure-Code): SM-108 → 1.20× worse mean TTFT, 1.19×
//! worse P90, −13% goodput; SM-84 → 1.78× worse TTFT, −5.9% throughput;
//! no fixed point balances both metrics.

use bullet::baselines::{run_system, System};
use bullet::config::{ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::summarize;
use bullet::util::tbl::{f, ms, Table};
use bullet::workload::{generate_n_requests, Dataset};

fn main() {
    let n = 100;
    let seed = 13;
    for ds in Dataset::all() {
        let (slo, rate) = match ds.name {
            "azure-code" => (SloSpec::azure_code(), 5.0),
            "arxiv-summary" => (SloSpec::arxiv_summary(), 1.5),
            _ => (SloSpec::sharegpt(), 12.0),
        };
        let cfg = ServingConfig { slo, ..ServingConfig::default() };
        let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
        let trace = generate_n_requests(&ds, rate, n, seed);

        let mut t = Table::new(&format!("Fig. 13 — fixed prefill SMs, {} @ {} req/s", ds.name, rate))
            .header(&["config", "mean TTFT ms", "P90 TTFT ms", "mean TPOT ms", "tok/s", "SLO %"]);
        let mut results = Vec::new();
        for sys in [
            System::FixedSm(60),
            System::FixedSm(84),
            System::FixedSm(96),
            System::FixedSm(108),
            System::Bullet,
        ] {
            let recs = run_system(sys, &cfg, server.perf(), server.ground_truth(), &trace, seed);
            let s = summarize(&recs, &cfg.slo, None);
            t.row(&[
                sys.label(),
                ms(s.mean_ttft),
                ms(s.p90_ttft),
                ms(s.mean_tpot),
                f(s.throughput_tok_s, 0),
                f(s.slo_attainment * 100.0, 1),
            ]);
            results.push((sys.label(), s));
        }
        t.print();
        let bullet = &results.last().unwrap().1;
        for (label, s) in &results[..results.len() - 1] {
            println!(
                "  {label}: TTFT {:.2}x, TPOT {:.2}x, SLO {:+.1}pp vs Bullet",
                s.mean_ttft / bullet.mean_ttft,
                s.mean_tpot / bullet.mean_tpot.max(1e-9),
                (s.slo_attainment - bullet.slo_attainment) * 100.0,
            );
        }
        println!();
    }
    println!(
        "Shape check: small fixed partitions favour TPOT but inflate TTFT/tails; large ones do\n\
         the reverse; no static point matches dynamic tuning on both metrics simultaneously."
    );
}
