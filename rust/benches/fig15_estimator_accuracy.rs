//! Fig. 15: performance-estimator accuracy — SLO-compliance
//! classification accuracy (left panel) and predicted-vs-actual duration
//! error (right panel).
//!
//! Paper anchors: ~88% compliance-classification accuracy; ~19.1% mean
//! relative duration error — "the absolute error is inconsequential for
//! scheduling; only violation detection matters".
//!
//! Methodology note (DESIGN.md §6): the estimator and the simulated
//! hardware are deliberately different models — the estimator only knows
//! the Eq. 2 form and what the §3.2.2 profiling grid showed it; the
//! ground truth has hidden nonlinear scaling curves, graded bandwidth
//! interference and per-launch noise.

use bullet::config::{GpuSpec, ModelSpec, ServingConfig, SloSpec};
use bullet::gpu::roofline::GroundTruth;
use bullet::gpu::simulator::Simulator;
use bullet::gpu::stream::SmMask;
use bullet::model::phases::{decode_all_layers, prefill_layer_kernels, PhaseShape};
use bullet::perf::{profile, ProfileSpec};
use bullet::util::rng::Rng;
use bullet::util::stats;
use bullet::util::tbl::{f, Table};

fn main() {
    let cfg = ServingConfig::default();
    let model = ModelSpec::llama31_8b();
    let gt = GroundTruth::new(GpuSpec::a100()); // WITH noise — real conditions
    eprintln!("profiling (paper grid)...");
    let pm = profile(&GroundTruth::noiseless(GpuSpec::a100()), &model, &ProfileSpec::paper(&cfg.gpu));

    let mut rng = Rng::new(15);
    let mut rel_err_prefill = Vec::new();
    let mut rel_err_decode = Vec::new();
    let mut agree = 0usize;
    let mut total = 0usize;
    // Boundary cases — actual within 2x of the budget — are the ones the
    // scheduler actually sweats over; far-from-budget cases are trivially
    // classified by any model.
    let mut agree_boundary = 0usize;
    let mut total_boundary = 0usize;
    let slo = SloSpec::azure_code();

    // Probes replicate SERVING conditions: prefill and decode co-located
    // on complementary masks (the state the scheduler actually predicts
    // under).  The estimator models contention with the two fitted
    // constants p_c/p_b; the ground truth's contention depends on the
    // actual kernel mix + noise — that gap is the paper's ~19% MRE.
    for _ in 0..300 {
        let sl = rng.range_u64(200, 16000) as usize;
        let bs = rng.range_u64(1, 200) as usize;
        let cl = rng.range_u64(100, 8000) as usize;
        let pmx = (24 + 2 * rng.below(37) as usize).min(96);
        let dm = 108 - pmx;

        let mut sim = Simulator::new(gt.clone(), rng.next_u64());
        let ps = sim.create_stream(SmMask::first(pmx), "p");
        let ds = sim.create_stream(SmMask::last(dm, 108), "d");
        // one full prefill pass co-running with repeated decode steps
        for _ in 0..model.n_layers {
            sim.submit_all(ps, prefill_layer_kernels(&model, PhaseShape { tokens: sl, context: 0 }));
        }
        let decode_kernels = decode_all_layers(&model, PhaseShape { tokens: bs, context: cl });
        let n_dec = 4usize;
        for _ in 0..n_dec {
            sim.submit_all(ds, decode_kernels.clone());
        }
        sim.run_until_stream_idle(ps);
        let actual_prefill = sim.now();
        sim.run_until_idle();
        // average decode-iteration time from completions on the decode stream
        let comps = sim.take_completions();
        let dec_end = comps
            .iter()
            .filter(|c| c.stream == ds)
            .map(|c| c.end)
            .fold(0.0f64, f64::max);
        let actual_decode_iter = dec_end / n_dec as f64;

        let predicted_prefill =
            pm.predict_prefill_layer(sl, 0, pmx, true) * model.n_layers as f64;
        let predicted_decode = pm.predict_decode_step(bs, cl, dm, true);

        rel_err_prefill.push(((predicted_prefill - actual_prefill) / actual_prefill).abs());
        rel_err_decode.push(((predicted_decode - actual_decode_iter) / actual_decode_iter).abs());

        for (pred, act, budget) in [
            (predicted_prefill, actual_prefill, slo.ttft_budget(sl)),
            (predicted_decode, actual_decode_iter, slo.tpot_budget()),
        ] {
            let ok = (pred <= budget) == (act <= budget);
            agree += ok as usize;
            total += 1;
            if act > budget * 0.5 && act < budget * 2.0 {
                agree_boundary += ok as usize;
                total_boundary += 1;
            }
        }
    }

    let all_err: Vec<f64> = rel_err_prefill
        .iter()
        .chain(&rel_err_decode)
        .copied()
        .collect();
    let mut t = Table::new("Fig. 15 — estimator accuracy (ours vs paper)")
        .header(&["metric", "ours", "paper"]);
    t.row(&[
        "SLO classification accuracy %".to_string(),
        f(100.0 * agree as f64 / total as f64, 1),
        "88".to_string(),
    ]);
    t.row(&[
        "  near-boundary accuracy %".to_string(),
        f(100.0 * agree_boundary as f64 / total_boundary.max(1) as f64, 1),
        "-".to_string(),
    ]);
    t.row(&[
        "mean relative duration error %".to_string(),
        f(100.0 * stats::mean(&all_err), 1),
        "19.1".to_string(),
    ]);
    t.row(&[
        "  prefill-only MRE %".to_string(),
        f(100.0 * stats::mean(&rel_err_prefill), 1),
        "-".to_string(),
    ]);
    t.row(&[
        "  decode-only MRE %".to_string(),
        f(100.0 * stats::mean(&rel_err_decode), 1),
        "-".to_string(),
    ]);
    t.row(&[
        "P90 relative error %".to_string(),
        f(100.0 * stats::percentile(&all_err, 90.0), 1),
        "-".to_string(),
    ]);
    t.print();
    println!(
        "\nShape check: classification accuracy near the paper's ~88% while the duration error\n\
         stays in the tens of percent — sufficient for violation detection, as claimed."
    );
}
