//! Fig. 7: kernel speedup on m SMs normalized to the full GPU, against
//! the linear-scaling reference — compute-bound prefill kernels scale
//! SUB-linearly, memory-bound decode kernels SUPER-linearly.

use bullet::config::{GpuSpec, ModelSpec};
use bullet::gpu::roofline::GroundTruth;
use bullet::model::phases::{decode_layer_kernels, prefill_layer_kernels, PhaseShape};
use bullet::util::tbl::{f, Table};

fn main() {
    let model = ModelSpec::llama31_8b();
    let gpu = GpuSpec::a100();
    let gt = GroundTruth::noiseless(gpu.clone());

    let prefill = prefill_layer_kernels(&model, PhaseShape { tokens: 4096, context: 0 });
    let gemm = prefill[3].clone(); // MLP GEMM — compute bound
    let attn_p = prefill[1].clone(); // prefill attention
    let decode = decode_layer_kernels(&model, PhaseShape { tokens: 64, context: 2048 });
    let dec_attn = decode[1].clone(); // decode attention — memory bound
    let dec_gemm = decode[3].clone(); // weight-streaming GEMM

    let mut t = Table::new(
        "Fig. 7 — speedup at m SMs normalized to 108 SMs (linear reference = m/108)",
    )
    .header(&["SMs", "linear", "MLP GEMM", "PrefillAttn", "DecodeAttn", "DecodeGEMM"]);

    for m in (6..=108).step_by(6) {
        let rel = |k: &bullet::gpu::KernelDesc| gt.solo_time(k, 108) / gt.solo_time(k, m);
        t.row(&[
            m.to_string(),
            f(m as f64 / 108.0, 3),
            f(rel(&gemm), 3),
            f(rel(&attn_p), 3),
            f(rel(&dec_attn), 3),
            f(rel(&dec_gemm), 3),
        ]);
    }
    t.print();
    println!(
        "\nShape check (paper): compute-intensive prefill columns sit BELOW the linear column\n\
         (susceptible to SM restriction); memory-bound decode columns sit ABOVE it (super-linear\n\
         — a small partition still draws most of the HBM bandwidth). This asymmetry is exactly\n\
         why giving decode few SMs and prefill many maximizes aggregate utilization."
    );
}
