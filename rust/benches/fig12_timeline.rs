//! Fig. 12: system state over time on Azure-Code @ 5 req/s — dynamic
//! prefill-SM allocation tracking load (top), concurrent tokens/batch
//! (middle), waiting queue (bottom) — plus the SGLang-2048 comparison.
//!
//! Paper anchors: on bursts Bullet sets prefill SMs to (near-)full GPU
//! and may delay decodes, then returns to a balance point; SGLang-2048
//! suffers 4.17× longer queuing; Bullet cuts TTFT 9.15× and TPOT 1.33×.

use bullet::baselines::{run_system, System};
use bullet::config::{ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::summarize;
use bullet::util::tbl::bar;
use bullet::workload::{generate_bursty_trace, Dataset};

fn main() {
    let cfg = ServingConfig {
        slo: SloSpec::azure_code(),
        ..ServingConfig::default()
    };
    let mut server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    server.record_timeline(true);

    // Azure-Code at 5 req/s with a brief heavier window — the paper's
    // trace is plain Poisson at 5 req/s whose natural clustering makes
    // the "request rate bursts"; we add a short 8 req/s window so the
    // burst lands deterministically in the plotted span.
    let trace = generate_bursty_trace(&Dataset::azure_code(), 5.0, 8.0, 40.0, 15.0, 6.0, 11);
    println!(
        "Fig. 12 — Azure-Code @ 5 req/s (8 req/s window at t=15..21s, {} requests)\n",
        trace.len()
    );
    let out = server.serve(&trace);

    println!("t(s)   prefill-SM allocation     tokens  batch  waiting");
    for s in out.timeline.resample(1.0) {
        println!(
            "{:5.1}  [{}] {:>3}   {:>6}  {:>5}  {:>3} {}",
            s.t,
            bar(s.prefill_sms as f64 / cfg.gpu.num_sms as f64, 20),
            s.prefill_sms,
            s.prefill_tokens,
            s.decode_batch,
            s.waiting,
            if s.waiting > 5 { "<- burst" } else { "" },
        );
    }

    let bullet = summarize(&out.records, &cfg.slo, None);
    let sg = summarize(
        &run_system(System::Sglang2048, &cfg, server.perf(), server.ground_truth(), &trace, 11),
        &cfg.slo,
        None,
    );
    println!(
        "\n                 Bullet     SGLang-2048   ratio (paper)\n\
         mean TTFT (ms)  {:>8.0}  {:>10.0}   {:>5.2}x (9.15x)\n\
         mean TPOT (ms)  {:>8.1}  {:>10.1}   {:>5.2}x (1.33x)\n\
         queueing (ms)   {:>8.0}  {:>10.0}   {:>5.2}x (4.17x)",
        bullet.mean_ttft * 1e3,
        sg.mean_ttft * 1e3,
        sg.mean_ttft / bullet.mean_ttft,
        bullet.mean_tpot * 1e3,
        sg.mean_tpot * 1e3,
        sg.mean_tpot / bullet.mean_tpot,
        bullet.mean_queueing * 1e3,
        sg.mean_queueing * 1e3,
        sg.mean_queueing / bullet.mean_queueing.max(1e-6),
    );
    println!(
        "\nShape check: prefill-SM allocation spikes to (near) full GPU during the burst and\n\
         relaxes to a balance point afterwards; the waiting queue never builds up the way the\n\
         budget-limited chunked system's does."
    );
}
