//! Table 1: theoretical SM idle ratio (%) from wave quantization,
//! per operator, normalized to the layer's execution time — Eq. 1 over
//! Llama-3.1-8B's per-operator grids on a 108-SM A100.

use bullet::config::{GpuSpec, ModelSpec};
use bullet::gpu::roofline::GroundTruth;
use bullet::gpu::wave_quantization_idle_ratio;
use bullet::model::phases::{prefill_layer_kernels, PhaseShape};
use bullet::util::tbl::{f, Table};

fn main() {
    let model = ModelSpec::llama31_8b();
    let gpu = GpuSpec::a100();
    let gt = GroundTruth::noiseless(gpu.clone());

    // paper's reported rows for side-by-side comparison
    let paper: &[(usize, [f64; 5])] = &[
        (1024, [11.1, 21.0, 40.7, 13.0, 19.4]),
        (2048, [11.1, 5.2, 21.0, 7.6, 10.4]),
        (4096, [11.1, 5.2, 5.2, 7.6, 9.1]),
        (16384, [1.9, 0.2, 0.2, 0.4, 0.5]),
    ];

    let mut t = Table::new(
        "Table 1 — SM idle ratio (%) from wave quantization (ours vs paper in parens)",
    )
    .header(&["SeqLen", "QKV", "Attn", "OProj", "MLP", "Total"]);

    for &(sl, pap) in paper {
        let ks = prefill_layer_kernels(&model, PhaseShape { tokens: sl, context: 0 });
        let times: Vec<f64> = ks.iter().map(|k| gt.solo_time(k, gpu.num_sms)).collect();
        // time-weighted idle ratio over a set of kernel indices
        let weighted = |idx: &[usize]| -> f64 {
            let tt: f64 = idx.iter().map(|&i| times[i]).sum();
            idx.iter()
                .map(|&i| {
                    100.0 * wave_quantization_idle_ratio(ks[i].grid, gpu.num_sms) * times[i] / tt
                })
                .sum()
        };
        // layout: 0 QKV, 1 Attn, 2 OProj, 3+4 MLP (gate/up + down), 5 elemwise
        let qkv = weighted(&[0]);
        let attn = weighted(&[1]);
        let oproj = weighted(&[2]);
        let mlp = weighted(&[3, 4]);
        let total = weighted(&[0, 1, 2, 3, 4]);
        t.row(&[
            sl.to_string(),
            format!("{} ({})", f(qkv, 1), pap[0]),
            format!("{} ({})", f(attn, 1), pap[1]),
            format!("{} ({})", f(oproj, 1), pap[2]),
            format!("{} ({})", f(mlp, 1), pap[3]),
            format!("{} ({})", f(total, 1), pap[4]),
        ]);
    }
    t.print();
    println!(
        "\nShape check: idle ratio decays with sequence length (19%-class at 1k -> <2% at 16k),\n\
         QKV flat at 11.1% through 1k-4k, attention worst at 1k. Grid heuristics: 128x128 GEMM\n\
         tiles, 128-row FlashAttention query blocks (see model::phases)."
    );
}
