//! Table 3: control-plane overheads, measured in real wall-clock on
//! OUR implementations of the three mechanisms:
//!   - metadata send/recv   (paper: 0.21 ms mean — python pickling; ours
//!                           is an in-process atomic board + handoff ring)
//!   - performance predict  (paper: 10.2 µs)
//!   - resource re-config   (paper: 4.1 µs — pre-built masked streams)

use bullet::config::{GpuSpec, ModelSpec};
use bullet::engine::metadata::{Handoff, MetadataBuffer};
use bullet::gpu::roofline::GroundTruth;
use bullet::gpu::simulator::Simulator;
use bullet::perf::{profile, PerfModel, ProfileSpec};
use bullet::resource::{Partition, ResourceManager};
use bullet::util::stats;
use bullet::util::tbl::{f, Table};
use std::time::Instant;

fn percentiles(samples: &mut [f64]) -> (f64, f64, f64, f64) {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        stats::mean(samples),
        stats::stddev(samples),
        stats::percentile_sorted(samples, 90.0),
        stats::percentile_sorted(samples, 99.0),
    )
}

fn main() {
    let gpu = GpuSpec::a100();
    let model = ModelSpec::llama31_8b();
    let n = 20_000usize;

    // --- metadata send/recv: cross-thread handoff + status roundtrip ---
    let meta = std::sync::Arc::new(MetadataBuffer::new());
    let mut meta_lat = Vec::with_capacity(n);
    for i in 0..n {
        let t0 = Instant::now();
        meta.publish_prefill(1024, (i % 32) as usize, 3);
        meta.push_handoff(Handoff {
            req_id: i as u64,
            seq_id: i as u64,
            input_len: 1024,
            output_len: 64,
            first_token: 1,
            first_token_time: 0.0,
            arrival: 0.0,
            prefill_start: 0.0,
        });
        let got = meta.drain_handoffs(4);
        let _ = meta.snapshot_decode();
        std::hint::black_box(got);
        meta_lat.push(t0.elapsed().as_secs_f64() * 1e3); // ms
    }

    // --- performance prediction ---
    let pm = profile(
        &GroundTruth::noiseless(gpu.clone()),
        &model,
        &ProfileSpec::coarse(&gpu),
    );
    let mut pred_lat = Vec::with_capacity(n);
    for i in 0..n {
        let sl = 256 + (i % 64) * 128;
        let t0 = Instant::now();
        let a = pm.predict_prefill_layer(sl, 0, 54 + (i % 4) * 6, true);
        let b = pm.predict_decode_step(32 + i % 32, 1024 + (i % 8) * 512, 54, true);
        std::hint::black_box(a + b);
        pred_lat.push(t0.elapsed().as_secs_f64() * 1e6); // us
    }

    // --- resource re-configuration: pre-built masked-stream switch ---
    let mut sim = Simulator::new(GroundTruth::noiseless(gpu.clone()), 3);
    let mut rm = ResourceManager::new(&mut sim, &gpu);
    let mut reconf_lat = Vec::with_capacity(n);
    for i in 0..n {
        let pmx = 6 + (i % 50) * 2;
        let t0 = Instant::now();
        rm.reconfigure(Partition { prefill_sms: pmx, decode_sms: 108 - pmx });
        std::hint::black_box((rm.prefill_stream(), rm.decode_stream()));
        reconf_lat.push(t0.elapsed().as_secs_f64() * 1e6); // us
    }

    let (m1, s1, p901, p991) = percentiles(&mut meta_lat);
    let (m2, s2, p902, p992) = percentiles(&mut pred_lat);
    let (m3, s3, p903, p993) = percentiles(&mut reconf_lat);

    let mut t = Table::new("Table 3 — Bullet control-plane overheads (ours; paper in parens)")
        .header(&["component", "mean", "std", "P90", "P99"]);
    t.row(&[
        "Metadata Send/Recv (ms)".to_string(),
        format!("{} (0.21)", f(m1, 4)),
        format!("{} (0.44)", f(s1, 4)),
        format!("{} (0.89)", f(p901, 4)),
        format!("{} (1.54)", f(p991, 4)),
    ]);
    t.row(&[
        "Performance Predict (us)".to_string(),
        format!("{} (10.2)", f(m2, 2)),
        format!("{} (5.1)", f(s2, 2)),
        format!("{} (24.5)", f(p902, 2)),
        format!("{} (25.8)", f(p992, 2)),
    ]);
    t.row(&[
        "Resource Re-config (us)".to_string(),
        format!("{} (4.1)", f(m3, 3)),
        format!("{} (0.79)", f(s3, 3)),
        format!("{} (4.2)", f(p903, 3)),
        format!("{} (5.9)", f(p993, 3)),
    ]);
    t.print();
    println!(
        "\nShape check: every mechanism is at or below the paper's budget — prediction and\n\
         re-configuration are microsecond-scale, metadata exchange sub-millisecond (our board\n\
         is in-process atomics rather than pickled python objects, hence the larger margin)."
    );
}
