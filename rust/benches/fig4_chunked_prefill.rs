//! Fig. 4: per-chunk GPU utilization and latency for a 16k-token prefill
//! under chunk sizes 1k and 2k (no hybrid batching).
//!
//! Paper anchors @ cs=1k: utilization decays ~71% → ~61% across chunks;
//! the final chunk takes ~1.9× the first; total prefill 1.13× unchunked.
//! @ cs=2k: util drop shrinks (−18% → −7%) but per-chunk latency is
//! ~1.86× the 1k chunks.

use bullet::config::{GpuSpec, ModelSpec};
use bullet::gpu::roofline::GroundTruth;
use bullet::gpu::simulator::Simulator;
use bullet::gpu::stream::SmMask;
use bullet::model::phases::{prefill_all_layers, PhaseShape};
use bullet::util::tbl::{f, Table};

const TOTAL_TOKENS: usize = 16384;

fn run_chunked(gt: &GroundTruth, model: &ModelSpec, cs: usize) -> Vec<(f64, f64)> {
    // returns per-chunk (latency, compute utilization)
    let mut out = Vec::new();
    let mut ctx = 0usize;
    while ctx < TOTAL_TOKENS {
        let chunk = cs.min(TOTAL_TOKENS - ctx);
        let mut sim = Simulator::new(gt.clone(), 1);
        let st = sim.create_stream(SmMask::first(gt.gpu.num_sms), "prefill");
        sim.submit_all(st, prefill_all_layers(model, PhaseShape { tokens: chunk, context: ctx }));
        sim.run_until_idle();
        let u = sim.total_util();
        out.push((sim.now(), u.compute_util(&gt.gpu)));
        ctx += chunk;
    }
    out
}

fn main() {
    let model = ModelSpec::llama31_8b();
    let gt = GroundTruth::noiseless(GpuSpec::a100());

    // unchunked reference
    let unchunked = run_chunked(&gt, &model, TOTAL_TOKENS);
    let t_unchunked = unchunked[0].0;

    for &cs in &[1024usize, 2048] {
        let chunks = run_chunked(&gt, &model, cs);
        let mut t = Table::new(&format!("Fig. 4 — 16k-token prefill, chunk size {cs}"))
            .header(&["chunk#", "latency ms", "compute util %"]);
        for (i, (lat, cu)) in chunks.iter().enumerate() {
            if i < 4 || i + 2 > chunks.len() || i % 4 == 3 {
                t.row(&[
                    (i + 1).to_string(),
                    f(lat * 1e3, 1),
                    f(cu * 100.0, 1),
                ]);
            }
        }
        t.print();
        let total: f64 = chunks.iter().map(|c| c.0).sum();
        let first = chunks[0].0;
        let last = chunks.last().unwrap().0;
        let u_first = chunks[0].1 * 100.0;
        let u_last = chunks.last().unwrap().1 * 100.0;
        println!(
            "summary cs={cs}: util {:.1}% -> {:.1}% | last/first chunk latency {:.2}x | \
             total {:.2}s = {:.2}x unchunked ({:.2}s)\n",
            u_first,
            u_last,
            last / first,
            total,
            total / t_unchunked,
            t_unchunked
        );
    }
    println!(
        "Shape check (paper): utilization decays across chunks from KV reloads; the final 1k\n\
         chunk runs ~1.9x the first; chunked total exceeds unchunked (1.13x at cs=1k); doubling\n\
         the chunk halves the relative util drop but ~doubles per-chunk latency (TPOT cost)."
    );
}
