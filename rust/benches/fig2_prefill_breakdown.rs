//! Fig. 2: prefill execution-time breakdown and compute/bandwidth
//! utilization per operator — Llama-3.1-8B on the simulated A100.
//!
//! Paper anchors: MLP up to 92% compute util; whole layers sustain only
//! 70–76%; OProj 49% at short seq vs 70% at long; attention dominates
//! (~34%) at long sequences; everything below the "peak sustainable"
//! line.

use bullet::config::{GpuSpec, ModelSpec};
use bullet::gpu::roofline::GroundTruth;
use bullet::model::phases::{prefill_layer_kernels, PhaseShape};
use bullet::util::tbl::{f, Table};

fn main() {
    let model = ModelSpec::llama31_8b();
    let gpu = GpuSpec::a100();
    let gt = GroundTruth::noiseless(gpu.clone());

    for &sl in &[1024usize, 2048, 4096, 8192, 16384] {
        let ks = prefill_layer_kernels(&model, PhaseShape { tokens: sl, context: 0 });
        let times: Vec<f64> = ks.iter().map(|k| gt.solo_time(k, gpu.num_sms)).collect();
        let total: f64 = times.iter().sum();
        let mut t = Table::new(&format!(
            "Fig. 2 — prefill layer breakdown @ sl={sl} (peak-sustainable line: {:.0}%)",
            gpu.sustainable_frac * 100.0
        ))
        .header(&["op", "time %", "compute util %", "bandwidth util %"]);
        let mut layer_cu = 0.0;
        let mut layer_bu = 0.0;
        for (k, &dt) in ks.iter().zip(&times) {
            let cu = 100.0 * gt.solo_compute_utilization(k, gpu.num_sms);
            let bu = 100.0 * gt.solo_bandwidth_utilization(k, gpu.num_sms);
            layer_cu += cu * dt / total;
            layer_bu += bu * dt / total;
            t.row(&[
                k.op.label().to_string(),
                f(100.0 * dt / total, 1),
                f(cu, 1),
                f(bu, 1),
            ]);
        }
        t.row(&[
            "LAYER".to_string(),
            "100.0".to_string(),
            f(layer_cu, 1),
            f(layer_bu, 1),
        ]);
        t.print();
        println!();
    }
    println!(
        "Shape check: whole-layer compute utilization sits in the paper's 60-76% band and never\n\
         reaches the peak-sustainable line; attention's share of time grows with sequence length;\n\
         OProj utilization recovers from wave quantization as sequences lengthen."
    );
}
