//! §Perf hot-path benchmarks (not a paper figure): the four L3 paths
//! that bound serving overhead and simulation turnaround —
//!   1. scheduler decision latency (paper budget: predict 10.2 µs +
//!      re-config 4.1 µs per cycle),
//!   2. simulator event throughput,
//!   3. end-to-end simulated serving wall time (Fig. 11-sized run),
//!   4. serving-core dispatch overhead: the `ServingPolicy` trait
//!      indirection versus a monomorphized engine loop must stay <1%,
//!   5. prefix-index longest-match lookup — the admission fast path the
//!      session/prefix-reuse subsystem adds to every arrival.
//!   6. correction-grid interpolation on WIDE profiled axes — one interp
//!      per candidate partition per scheduling cycle; `locate` is a
//!      binary search (`partition_point`), so paper-fidelity and wider
//!      grids stay off the decision budget.
//!   7. cluster-router decision latency on a 64-replica fleet — the
//!      front-door cost every arrival pays; routing reads frozen
//!      `ReplicaSignals` snapshots, so this is a pure argmin scan (the
//!      slo-slack perf-estimator probe is memoized per (sms, contended)
//!      key, so steady-state routing is probe-free).
//!   8. scheduler full-cycle latency vs queue depth ({8, 64, 512}
//!      waiting), hoisted per-cycle aggregates (`memo` on) vs the
//!      reference evaluator — asserts ≥2x at 512 waiting.
//!   9. simulator step throughput at {2, 8} concurrent streams.
//!   10. calibrated prediction, memoized vs cold `OnlineCalibrator`.
//! EXPERIMENTS.md §Perf records before/after for each optimization.

use bullet::cluster::{Dispatcher, ReplicaSignals, RouterPolicy};
use bullet::config::{CalibrationConfig, GpuSpec, ModelSpec, ServingConfig};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::engine::{BulletPolicy, CoreOptions, EngineCore, Features, ServingPolicy};
use bullet::gpu::roofline::GroundTruth;
use bullet::gpu::simulator::Simulator;
use bullet::gpu::stream::SmMask;
use bullet::gpu::{KernelDesc, OpClass};
use bullet::kvcache::prefix::PrefixIndex;
use bullet::kvcache::{KvPool, BLOCK_TOKENS};
use bullet::perf::{CalibrationStats, OnlineCalibrator, PerfModel, PerfPredictor};
use bullet::resource::Partition;
use bullet::sched::{DecodeReqState, PrefillBatch, PrefillReq, SloScheduler, SystemState};
use bullet::testing::bench::{bench, black_box};
use bullet::testing::content_chain;
use bullet::workload::{generate_n_requests, Dataset, Request};
use std::time::Instant;

fn loaded_state() -> SystemState {
    loaded_state_with(16)
}

fn loaded_state_with(n_waiting: u64) -> SystemState {
    let decode: Vec<DecodeReqState> = (0..128)
        .map(|i| DecodeReqState {
            id: i,
            input_len: 1024,
            ctx_len: 1024 + (i as usize * 13) % 4096,
            tokens_out: 10 + (i as usize % 50),
            output_len: 200,
            decode_elapsed: 0.5,
        })
        .collect();
    let waiting: Vec<PrefillReq> = (0..n_waiting)
        .map(|i| PrefillReq {
            id: 500 + i,
            arrival: i as f64 * 0.01,
            input_len: 512 + (i as usize * 731) % 8192,
            output_len: 128,
            ..Default::default()
        })
        .collect();
    SystemState {
        now: 5.0,
        prefill: Some(PrefillBatch {
            reqs: vec![PrefillReq {
                id: 1,
                arrival: 4.0,
                input_len: 6000,
                output_len: 100,
                ..Default::default()
            }],
            n_tokens: 6000,
            layers_done: 10,
            started_at: 4.5,
            ..Default::default()
        }),
        decode,
        waiting,
        partition: Partition::split(&GpuSpec::a100(), 72),
        total_layers: 32,
    }
}

fn main() {
    // 1. scheduler decision latency under a heavy state
    let cfg = ServingConfig::default();
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let sched = SloScheduler::new(cfg.clone(), perf);
    let st = loaded_state();
    let r = bench("scheduler decision (128-req decode, 16 waiting)", 200, || {
        let mut s = st.clone();
        black_box(sched.schedule(&mut s));
    });
    println!("{}", r.report());

    // 2. simulator event throughput
    let gt = GroundTruth::new(GpuSpec::a100());
    let t0 = Instant::now();
    let mut events = 0usize;
    let mut sim = Simulator::new(gt.clone(), 1);
    let a = sim.create_stream(SmMask::first(72), "a");
    let b = sim.create_stream(SmMask::last(36, 108), "b");
    for _ in 0..20_000 {
        sim.submit(a, KernelDesc::new(OpClass::GemmMlp, 1e11, 1e8, 512));
        sim.submit(b, KernelDesc::new(OpClass::AttnDecode, 1e9, 5e8, 64));
    }
    while sim.step() {
        events += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "simulator: {events} kernel completions in {:.2}s = {:.0} events/s",
        dt,
        events as f64 / dt
    );

    // 3. end-to-end simulated serving (Fig. 11-sized single cell)
    let server = BulletServer::build(cfg.clone(), BuildOptions::default());
    let trace = generate_n_requests(&Dataset::sharegpt(), 15.0, 120, 42);
    let t0 = Instant::now();
    let out = server.serve(&trace);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "serve_bullet: 120 sharegpt reqs ({} virtual s) in {:.2}s wall = {:.1}x realtime",
        out.virtual_duration as u64,
        dt,
        out.virtual_duration / dt
    );

    // 4. serving-core dispatch overhead: identical Bullet run driven by a
    //    monomorphized policy vs a boxed `dyn ServingPolicy` (the cluster
    //    layer's configuration).  The refactor's contract is <1% overhead
    //    versus the pre-refactor inlined loop, which static dispatch
    //    reproduces (the policy calls inline into the pump).
    let gt2 = GroundTruth::new(GpuSpec::a100());
    let perf2 = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let dispatch_trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 60, 7);
    let serve_static = |cfg: &ServingConfig, trace: &[Request]| -> usize {
        let mut core =
            EngineCore::new(cfg.clone(), gt2.clone(), trace.to_vec(), &CoreOptions::default());
        let mut policy = BulletPolicy::new(cfg, &perf2, Features::default());
        core.run(&mut policy);
        core.into_output().records.len()
    };
    let serve_dyn = |cfg: &ServingConfig, trace: &[Request]| -> usize {
        let mut core =
            EngineCore::new(cfg.clone(), gt2.clone(), trace.to_vec(), &CoreOptions::default());
        let mut policy: Box<dyn ServingPolicy> =
            Box::new(BulletPolicy::new(cfg, &perf2, Features::default()));
        core.run(policy.as_mut());
        core.into_output().records.len()
    };
    // min-of-N to reject scheduling noise; interleave the two variants.
    let reps = 5;
    let mut t_static = f64::INFINITY;
    let mut t_dyn = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(serve_static(&cfg, &dispatch_trace));
        t_static = t_static.min(t0.elapsed().as_secs_f64());
        let t0 = Instant::now();
        black_box(serve_dyn(&cfg, &dispatch_trace));
        t_dyn = t_dyn.min(t0.elapsed().as_secs_f64());
    }
    let overhead_pct = (t_dyn - t_static) / t_static * 100.0;
    println!(
        "harness dispatch: static {:.1}ms vs dyn {:.1}ms per 60-req serve = {:+.2}% overhead {}",
        t_static * 1e3,
        t_dyn * 1e3,
        overhead_pct,
        if overhead_pct < 1.0 { "(<1% bar: OK)" } else { "(ABOVE the 1% bar!)" }
    );

    // 5. prefix-index longest-match lookup: the per-arrival admission
    //    fast path.  256 cached chains of 32 blocks; the probe shares 32
    //    blocks with one of them and then diverges for another 32 — the
    //    worst case that still walks a full cached prefix.
    let mut pool = KvPool::new(16 * 1024 * BLOCK_TOKENS);
    let mut index = PrefixIndex::new();
    let contents = |c: u64, b: u64| (c << 32) | b; // unique per (chain, block)
    for c in 0..256u64 {
        let chain = content_chain(&(0..32).map(|b| contents(c, b)).collect::<Vec<_>>());
        let id = 9000 + c;
        pool.grow(id, 32 * BLOCK_TOKENS).unwrap();
        let blocks = pool.get(id).unwrap().blocks.clone();
        index.insert(&mut pool, &chain, &blocks);
    }
    // the probe shares chain 171's 32 blocks, then diverges for 32 more
    let probe_contents: Vec<u64> = (0..32)
        .map(|b| contents(171, b))
        .chain((0..32).map(|b| contents(0xF00D, b)))
        .collect();
    let probe = content_chain(&probe_contents);
    let prompt_tokens = probe.len() * BLOCK_TOKENS + 8;
    let r = bench(
        "prefix-index longest-match (256 chains x 32 blocks, 64-block probe)",
        2000,
        || {
            black_box(index.lookup(black_box(&probe), prompt_tokens));
        },
    );
    println!("{}", r.report());

    // 6. correction-grid interpolation on wide axes.  The narrow case
    //    mirrors the coarse test grid; the wide case is far past the
    //    paper grid (512/256/128 knots) — with binary-search `locate`
    //    both should bench within the same order of magnitude.
    use bullet::perf::grid::Grid3;
    let make_grid = |n0: usize, n1: usize, n2: usize| {
        let axis = |n: usize| (0..n).map(|i| (i * i) as f64 + i as f64).collect::<Vec<_>>();
        Grid3::new(axis(n0), axis(n1), axis(n2), 1.0)
    };
    let narrow = make_grid(3, 2, 3);
    let wide = make_grid(512, 256, 128);
    let probes: Vec<(f64, f64, f64)> = (0..64)
        .map(|i| {
            let x = (i * 4001 % 262144) as f64;
            (x, x * 0.3, x * 0.1)
        })
        .collect();
    for (label, grid) in [("3x2x3 (coarse)", &narrow), ("512x256x128 (wide)", &wide)] {
        let r = bench(&format!("Grid3 interp, {label}, 64 probes"), 5000, || {
            let mut acc = 0.0;
            for &(a, b, c) in &probes {
                acc += grid.interp(black_box(a), black_box(b), black_box(c));
            }
            black_box(acc);
        });
        println!("{}", r.report());
    }

    // 7. cluster-router decision latency, 64-replica fleet.  Signals are
    //    frozen snapshots (exactly what the dispatch loop hands the
    //    router), staggered so the argmin never short-circuits on a
    //    trivially uniform fleet.  slo-slack additionally runs one
    //    perf-estimator probe per replica per arrival — the most
    //    expensive policy — while least-kv is the pure scan floor.
    let fleet: Vec<ReplicaSignals> = (0..64)
        .map(|i| ReplicaSignals {
            id: i,
            outstanding_kv_tokens: 40_000 + (i * 977) % 30_000,
            backlog_tokens: 2_000 + (i * 313) % 9_000,
            decode_batch: i % 48,
            num_sms: 108,
            n_layers: 32,
            slowdown: 1.0 + (i % 7) as f64 * 0.05,
            calib: CalibrationStats::default(),
            drained: false,
        })
        .collect();
    let eligible: Vec<usize> = (0..fleet.len()).collect();
    let route_req = Request { input_len: 2048, output_len: 128, ..Default::default() };
    let perf3 = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    for policy in [RouterPolicy::LeastKv, RouterPolicy::SloSlack] {
        let mut d = Dispatcher::new(policy);
        let r = bench(&format!("router pick_among ({}, 64 replicas)", policy.label()), 5000, || {
            black_box(d.pick_among(
                black_box(&fleet),
                black_box(&eligible),
                black_box(&route_req),
                &perf3,
                &cfg.slo,
            ));
        });
        println!("{}", r.report());
    }

    // 8. scheduler full-cycle latency vs queue depth, hoisted per-cycle
    //    aggregates (memo on, the default) vs the reference evaluator
    //    (memo off).  Identical decisions by construction (the parity
    //    tests assert it bit-for-bit); this case measures the speedup
    //    and enforces the PR-8 floor: ≥2x at 512 waiting.
    for n_wait in [8u64, 64, 512] {
        let st = loaded_state_with(n_wait);
        let mk_perf = || PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let sched_on = SloScheduler::new(cfg.clone(), mk_perf());
        let cfg_off = ServingConfig { memo: false, ..cfg.clone() };
        let sched_off = SloScheduler::new(cfg_off, mk_perf());
        let r_on = bench(&format!("schedule() memo on ({n_wait} waiting)"), 200, || {
            let mut s = st.clone();
            black_box(sched_on.schedule(&mut s));
        });
        let r_off = bench(&format!("schedule() memo off ({n_wait} waiting)"), 200, || {
            let mut s = st.clone();
            black_box(sched_off.schedule(&mut s));
        });
        let speedup = r_off.min_s / r_on.min_s;
        println!("{}", r_on.report());
        println!("{}", r_off.report());
        println!("scheduler cycle speedup @ {n_wait} waiting: {speedup:.2}x");
        if n_wait == 512 {
            assert!(
                speedup >= 2.0,
                "scheduler hoisting must be ≥2x at 512 waiting, got {speedup:.2}x"
            );
        }
    }

    // 9. simulator step throughput at {2, 8} concurrent streams:
    //    overlapping masks, mixed compute/memory kernels, step-to-
    //    completion driving (each step lands on a completion, so this
    //    exercises invalidation, not steady-state reuse).
    for n_streams in [2usize, 8] {
        let t0 = Instant::now();
        let mut events = 0usize;
        let mut sim = Simulator::new(gt.clone(), 1);
        let ids: Vec<_> = (0..n_streams)
            .map(|i| sim.create_stream(SmMask::first(36 + (i * 9) % 72), &format!("s{i}")))
            .collect();
        for j in 0..(40_000 / n_streams) {
            for (i, &s) in ids.iter().enumerate() {
                let k = if (i + j) % 2 == 0 {
                    KernelDesc::new(OpClass::GemmMlp, 1e11, 1e8, 512)
                } else {
                    KernelDesc::new(OpClass::AttnDecode, 1e9, 5e8, 64)
                };
                sim.submit(s, k);
            }
        }
        while sim.step() {
            events += 1;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "simulator ({n_streams} streams): {events} completions in {dt:.2}s = {:.0} events/s",
            events as f64 / dt
        );
    }

    // 10. calibrated prediction, memoized vs cold.  Cells are warmed
    //     first so blend() does real work; the 64-shape probe set mimics
    //     one scheduling cycle's candidate scan (few distinct shapes,
    //     many repeats).
    let mut cal = OnlineCalibrator::new(perf3.clone(), CalibrationConfig::on());
    let obs_base = PerfModel::predict_prefill_layer(cal.offline(), 2048, 0, 72, true);
    for _ in 0..20 {
        cal.observe_prefill(2048, 0, 72, true, 1, obs_base * 1.4);
    }
    for (label, memo) in [("memoized", true), ("cold", false)] {
        cal.set_memo(memo);
        let r = bench(&format!("calibrated predict ({label}, 64-probe cycle)"), 5000, || {
            let mut acc = 0.0;
            for i in 0..64usize {
                acc += cal.predict_prefill_layer(512 + (i * 97) % 4096, 0, 12 * (1 + i % 9), true);
            }
            black_box(acc);
        });
        println!("{}", r.report());
    }
}
