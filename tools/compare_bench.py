#!/usr/bin/env python3
"""Gate a fresh bench_runner artifact against the committed baseline.

Usage: compare_bench.py BASELINE.json FRESH.json

Three checks, in order of strictness:

1. **Parity (always enforced).** The fresh run must report
   ``cluster.parity: true`` — the parallel backend reproduced the serial
   backend bit-for-bit during the bench itself.  A diverging build's
   numbers are meaningless, so this fails hard.

2. **Speedup floor (enforced on >=6-core hosts).** The tentpole's
   acceptance bar is ~2x at 8 replicas on a 4-core runner.  Hosted CI
   runners are noisy and frequently oversubscribed, so the hard floor
   is 1.3x with a warning band up to 2.0x; below 6 cores the check is
   skipped entirely — shared 4-core runners flake on the floor even
   when the build is healthy, and a 2-core runner cannot hit 2x by
   construction.

3. **Simulator-throughput regression (enforced only against a verified
   baseline).** Fails when the fresh ``cluster.realtime_factor``
   (virtual seconds simulated per wall second, parallel backend) drops
   >15% below the baseline's.  The committed baseline starts with
   ``verified: false`` (authored before any runner executed it); promote
   a CI artifact to baseline — which flips ``verified`` to true — to arm
   this gate.  Wall-clock numbers from unverified baselines are
   estimates and must not fail builds.

4. **Competitor-system legs (ordering enforced, drift soft).** The
   ``systems.*`` keys record the Fig. 11/13-style comparison against the
   intra-GPU P/D disaggregation baselines.  Two *ordering* invariants
   are enforced on the fresh artifact (they are deterministic outcomes
   of the simulation, not wall-clock noise): Bullet's azure-code goodput
   must be >= every disaggregation baseline's, and the proactive split's
   bursty P90 TTFT must beat the static split's.  Per-key drift against
   the baseline is reported as soft WARNs only — these are simulated
   metrics, so they move whenever simulation semantics intentionally
   change (like the makespan tripwire).

5. **Hotpath micro-benchmarks (soft, with one armed gate).** Every
   shared ``hotpath.*`` key is compared and any regression beyond the
   tolerance prints a WARN — micro-benchmarks on shared runners are too
   noisy to hard-gate wholesale.  The exception is
   ``router_pick_slo_slack_us`` (the per-arrival front-door cost PR 8
   memoized): against a *verified* baseline a >15% regression fails
   hard, because that number reverting means the probe memo stopped
   working.

The deterministic ``cluster.virtual_makespan_s`` is also compared: a
change there means simulation *semantics* changed (not just speed), so
it is reported loudly but does not fail the job — intentional semantic
changes land with an updated baseline.  ``cluster.memo_parity`` (the
memoization-off reference run reproduced the memoized bits) is enforced
like ``parity`` whenever the fresh artifact reports it.
"""

import json
import sys

REGRESSION_TOLERANCE = 0.15  # >15% realtime-factor drop fails
SPEEDUP_HARD_FLOOR = 1.3
SPEEDUP_SOFT_FLOOR = 2.0
MIN_CORES_FOR_SPEEDUP_GATE = 6


def die(msg: str) -> None:
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    if len(sys.argv) != 3:
        die(f"usage: {sys.argv[0]} BASELINE.json FRESH.json")
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)

    fc = fresh["cluster"]

    # 1. parity: non-negotiable
    if fc.get("parity") is not True:
        die("fresh run reports parity=false: parallel backend diverged from serial")
    print("parity: OK (parallel backend bit-identical to serial)")
    if "memo_parity" in fc:
        if fc["memo_parity"] is not True:
            die("fresh run reports memo_parity=false: a hot-path cache leaked into output")
        print("memo parity: OK (memoization-off reference bit-identical)")

    # 2. speedup floor
    cores = int(fresh.get("host", {}).get("cores", 0))
    speedup = float(fc["speedup"])
    if cores < MIN_CORES_FOR_SPEEDUP_GATE:
        print(f"speedup: SKIPPED ({cores} cores < {MIN_CORES_FOR_SPEEDUP_GATE})")
    elif speedup < SPEEDUP_HARD_FLOOR:
        die(f"speedup {speedup:.2f}x below hard floor {SPEEDUP_HARD_FLOOR}x on {cores} cores")
    elif speedup < SPEEDUP_SOFT_FLOOR:
        print(
            f"speedup: WARN {speedup:.2f}x (floor {SPEEDUP_HARD_FLOOR}x OK, "
            f"target {SPEEDUP_SOFT_FLOOR}x missed on {cores} cores)"
        )
    else:
        print(f"speedup: OK {speedup:.2f}x on {cores} cores")

    # deterministic makespan: semantic-drift tripwire (report, don't fail)
    bm = float(base["cluster"]["virtual_makespan_s"])
    fm = float(fc["virtual_makespan_s"])
    if bm != fm:
        print(
            f"NOTE: virtual makespan changed {bm:.3f}s -> {fm:.3f}s — simulation "
            "semantics differ from baseline; update BENCH_8.json if intentional"
        )
    else:
        print(f"virtual makespan: unchanged ({fm:.3f}s)")

    # 4. competitor-system legs: enforce the ordering invariants on the
    # fresh artifact, soft-compare per-key drift against the baseline
    fs = fresh.get("systems", {})
    if fs:
        bullet_gp = fs.get("fig11_azure_goodput_bullet_req_s")
        if bullet_gp is not None:
            for key, val in sorted(fs.items()):
                if key.startswith("fig11_azure_goodput_") and float(val) > float(bullet_gp):
                    die(
                        f"systems {key} = {float(val):g} exceeds Bullet's goodput "
                        f"{float(bullet_gp):g} — a disaggregation baseline beat "
                        "spatial-temporal sharing"
                    )
            print(f"systems: OK (Bullet goodput {float(bullet_gp):g} req/s tops the fig11 leg)")
        pro = fs.get("fig13_bursty_p90_ttft_proactive_split_ms")
        sta = fs.get("fig13_bursty_p90_ttft_static_split_ms")
        if pro is not None and sta is not None:
            if float(pro) >= float(sta):
                die(
                    f"systems fig13 P90 TTFT: proactive {float(pro):g} ms >= static "
                    f"{float(sta):g} ms — the moving P/D boundary stopped beating "
                    "the frozen split"
                )
            print(f"systems: OK (bursty P90 TTFT proactive {float(pro):g} < static {float(sta):g} ms)")
        bs = base.get("systems", {})
        for key in sorted(set(bs) & set(fs)):
            bv, fv = float(bs[key]), float(fs[key])
            if bv <= 0.0:
                continue
            # goodput regresses downward; latency (ttft) regresses upward
            if "_goodput_" in key:
                regressed = fv < bv * (1.0 - REGRESSION_TOLERANCE)
            else:
                regressed = fv > bv * (1.0 + REGRESSION_TOLERANCE)
            if regressed:
                print(
                    f"systems {key}: WARN drifted {bv:g} -> {fv:g} (soft — simulated "
                    "metric; moves with intentional semantic changes)"
                )

    # 5. hotpath micro-numbers: soft warnings, except the armed
    # slo-slack router gate (the PR-8 memoized front-door cost)
    verified = base.get("verified") is True
    bh = base.get("hotpath", {})
    fh = fresh.get("hotpath", {})
    for key in sorted(set(bh) & set(fh)):
        bv, fv = float(bh[key]), float(fh[key])
        if bv <= 0.0:
            continue
        # throughput-style keys regress downward; latency keys upward
        if key.endswith("_per_s") or key.endswith("_speedup"):
            regressed = fv < bv * (1.0 - REGRESSION_TOLERANCE)
        else:
            regressed = fv > bv * (1.0 + REGRESSION_TOLERANCE)
        if not regressed:
            print(f"hotpath {key}: OK ({fv:g} vs baseline {bv:g})")
        elif key == "router_pick_slo_slack_us" and verified:
            die(
                f"hotpath {key} regressed {bv:g} -> {fv:g} "
                f"(> {REGRESSION_TOLERANCE:.0%} over a verified baseline — "
                "the router probe memo stopped paying for itself)"
            )
        else:
            print(f"hotpath {key}: WARN regressed {bv:g} -> {fv:g} (soft — micro-bench noise)")

    # 3. throughput regression vs a verified baseline only
    if not verified:
        print("regression: SKIPPED (baseline is unverified — promote a CI artifact to arm)")
        return
    brf = float(base["cluster"]["realtime_factor"])
    frf = float(fc["realtime_factor"])
    floor = brf * (1.0 - REGRESSION_TOLERANCE)
    if frf < floor:
        die(
            f"simulator throughput regressed: realtime factor {frf:.2f} < {floor:.2f} "
            f"(baseline {brf:.2f}, tolerance {REGRESSION_TOLERANCE:.0%})"
        )
    print(f"regression: OK (realtime factor {frf:.2f} vs baseline {brf:.2f})")


if __name__ == "__main__":
    main()
