#!/usr/bin/env python3
"""Validate and summarize a Bullet Chrome trace-event export.

Usage: trace_summary.py TRACE.json

Hard checks (exit 1 on any failure — CI's observability smoke gate):

1. **Document shape.** The file is valid JSON with a ``traceEvents``
   list (every event carries ``ph``/``pid``/``tid``, and a numeric
   ``ts`` unless it is an ``M`` metadata record) and a ``bullet``
   summary block with per-replica ``makespan`` + ``ledger`` entries and
   an aggregate ``ledger``.

2. **Ledger conservation.** For the aggregate and every replica, the
   seven attribution categories must sum to ``total`` (relative 1e-9,
   absolute floor 1.0 SM-second) — i.e. every simulated SM-second the
   run charged is present in the trace file, none double-counted, none
   leaked.  ``total`` itself must be positive for a run that served
   anything.

On success, prints the aggregate SM-second breakdown (category,
SM-seconds, share) so CI logs double as a utilization report.
"""

import json
import sys

CATEGORIES = [
    "prefill-compute",
    "prefill-attention",
    "decode",
    "wave-quant",
    "repartition",
    "kv-blocked",
    "idle",
]


def fail(msg):
    print(f"trace_summary: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_ledger(ledger, who):
    if not isinstance(ledger, dict):
        fail(f"{who}: ledger is not an object")
    for k in CATEGORIES + ["total"]:
        if k not in ledger:
            fail(f"{who}: ledger missing '{k}'")
        if not isinstance(ledger[k], (int, float)):
            fail(f"{who}: ledger['{k}'] is not a number")
        if ledger[k] != ledger[k]:  # NaN
            fail(f"{who}: ledger['{k}'] is NaN")
        if ledger[k] < 0:
            fail(f"{who}: ledger['{k}'] is negative ({ledger[k]})")
    total = ledger["total"]
    s = sum(ledger[k] for k in CATEGORIES)
    if abs(s - total) > 1e-9 * max(abs(total), 1.0):
        fail(f"{who}: categories sum to {s!r}, total says {total!r}")
    return total


def main():
    if len(sys.argv) != 2:
        print(__doc__.strip().splitlines()[2], file=sys.stderr)
        sys.exit(2)
    path = sys.argv[1]
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read '{path}': {e}")

    if not isinstance(doc, dict):
        fail("top level is not an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("missing 'traceEvents' list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"traceEvents[{i}] is not an object")
        for k in ("ph", "pid", "tid"):
            if k not in ev:
                fail(f"traceEvents[{i}] missing '{k}'")
        if ev["ph"] != "M" and not isinstance(ev.get("ts"), (int, float)):
            fail(f"traceEvents[{i}] ({ev['ph']!r}) missing numeric 'ts'")

    bullet = doc.get("bullet")
    if not isinstance(bullet, dict):
        fail("missing 'bullet' summary block")
    replicas = bullet.get("replicas")
    if not isinstance(replicas, list) or not replicas:
        fail("bullet.replicas missing or empty")
    for r in replicas:
        rid = r.get("id")
        if not isinstance(r.get("makespan"), (int, float)):
            fail(f"replica {rid}: missing numeric 'makespan'")
        check_ledger(r.get("ledger"), f"replica {rid}")
    agg = bullet.get("ledger")
    total = check_ledger(agg, "aggregate")
    if total <= 0:
        fail(f"aggregate ledger total is {total} — run served nothing?")

    title = bullet.get("title", "?")
    print(f"trace_summary: OK — {len(events)} events, {len(replicas)} replica(s)")
    print(f"GPU time attribution — {title}")
    width = max(len(c) for c in CATEGORIES + ["total"])
    for c in CATEGORIES:
        share = agg[c] / total * 100.0
        print(f"  {c:<{width}}  {agg[c]:>14.1f} SM·s  {share:>5.1f}%")
    print(f"  {'total':<{width}}  {total:>14.1f} SM·s  100.0%")


if __name__ == "__main__":
    main()
